//! Synthetic image classification task (the CIFAR/ImageNet stand-in).
//!
//! Each class owns a prototype built from a small bank of random 2D
//! sinusoid textures — a mix of *coarse* (low frequency, high contrast)
//! and *fine* (high frequency, low contrast) components.  Samples are
//! prototypes under random cyclic shift, horizontal flip, per-sample
//! brightness jitter and additive Gaussian noise.
//!
//! Why this preserves the paper's phenomena: class pairs that share
//! coarse components differ only in their fine components, and fine,
//! low-contrast structure is exactly what low-bitwidth activation
//! quantization destroys — so accuracy degrades smoothly with bitwidth
//! and layers differ in quantization sensitivity, which is what the
//! bitwidth search exploits.

use crate::runtime::Tensor;
use crate::util::Rng;

/// Generation parameters for one synthetic dataset.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub classes: usize,
    pub hw: usize,
    pub channels: usize,
    pub n_train: usize,
    pub n_test: usize,
    /// Additive Gaussian pixel noise (std).
    pub noise: f32,
    /// Pairs of classes that share coarse structure (hardness knob):
    /// fraction of the texture bank shared with the previous class.
    pub confusability: f32,
    pub seed: u64,
}

impl SynthSpec {
    /// CIFAR-10 stand-in matching `resnet20_synth`'s geometry.
    pub fn cifar_like(seed: u64) -> SynthSpec {
        SynthSpec {
            classes: 10,
            hw: 32,
            channels: 3,
            n_train: 2560,
            n_test: 1280,
            noise: 0.35,
            confusability: 0.5,
            seed,
        }
    }

    /// 40-class ImageNet-subsample stand-in for `resnet18_synth`.
    pub fn imagenet_like(seed: u64) -> SynthSpec {
        SynthSpec {
            classes: 40,
            hw: 32,
            channels: 3,
            n_train: 5120,
            n_test: 2560,
            noise: 0.3,
            confusability: 0.6,
            seed,
        }
    }

    /// Tiny task for unit/integration tests (`resnet8_tiny` geometry).
    pub fn tiny(seed: u64) -> SynthSpec {
        SynthSpec {
            classes: 10,
            hw: 16,
            channels: 3,
            n_train: 512,
            n_test: 256,
            noise: 0.25,
            confusability: 0.4,
            seed,
        }
    }
}

/// An in-memory labelled image set (NHWC f32).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub hw: usize,
    pub channels: usize,
    pub classes: usize,
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    fn sample_size(&self) -> usize {
        self.hw * self.hw * self.channels
    }

    /// Copy sample `i` into `out` (length `sample_size`).
    pub fn copy_sample(&self, i: usize, out: &mut [f32]) {
        let sz = self.sample_size();
        out.copy_from_slice(&self.images[i * sz..(i + 1) * sz]);
    }

    /// Materialize an explicit index set as (x, y) tensors.
    pub fn gather(&self, idx: &[usize]) -> (Tensor, Tensor) {
        let sz = self.sample_size();
        let mut x = vec![0f32; idx.len() * sz];
        let mut y = vec![0i32; idx.len()];
        for (row, &i) in idx.iter().enumerate() {
            self.copy_sample(i, &mut x[row * sz..(row + 1) * sz]);
            y[row] = self.labels[i];
        }
        (
            Tensor::from_f32(&[idx.len(), self.hw, self.hw, self.channels], x),
            Tensor::from_i32(&[idx.len()], y),
        )
    }

    /// Deterministic split into two disjoint subsets (first gets `frac`).
    /// Stratified per class so both halves see every class — the paper
    /// splits CIFAR's train set 50/50 into search-train/search-val.
    pub fn split(&self, frac: f64, seed: u64) -> (Dataset, Dataset) {
        let mut rng = Rng::new(seed ^ 0x5917);
        let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); self.classes];
        for (i, &l) in self.labels.iter().enumerate() {
            by_class[l as usize].push(i);
        }
        let (mut ia, mut ib) = (Vec::new(), Vec::new());
        for mut idxs in by_class {
            rng.shuffle(&mut idxs);
            let k = ((idxs.len() as f64) * frac).round() as usize;
            ia.extend_from_slice(&idxs[..k]);
            ib.extend_from_slice(&idxs[k..]);
        }
        rng.shuffle(&mut ia);
        rng.shuffle(&mut ib);
        (self.subset(&ia), self.subset(&ib))
    }

    /// Content fingerprint (sha256 over geometry + raw bytes) — the
    /// identity workers and coordinator compare so index-only phases
    /// provably batch over identical bytes (DESIGN.md §18).
    pub fn fingerprint(&self) -> [u8; 32] {
        crate::exec::wire::dataset_fingerprint(
            self.hw as u32,
            self.channels as u32,
            self.classes as u32,
            &self.images,
            &self.labels,
        )
    }

    fn subset(&self, idx: &[usize]) -> Dataset {
        let sz = self.sample_size();
        let mut images = Vec::with_capacity(idx.len() * sz);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            images.extend_from_slice(&self.images[i * sz..(i + 1) * sz]);
            labels.push(self.labels[i]);
        }
        Dataset { hw: self.hw, channels: self.channels, classes: self.classes, images, labels }
    }
}

/// One sinusoidal texture component.
#[derive(Clone)]
struct Texture {
    fx: f32,
    fy: f32,
    phase: f32,
    amp: f32,
    color: [f32; 3],
}

fn texture_bank(rng: &mut Rng, coarse: bool, count: usize) -> Vec<Texture> {
    (0..count)
        .map(|_| {
            let (fmin, fmax, amp) = if coarse {
                (1.0, 3.0, 1.0) // low frequency, high contrast
            } else {
                (5.0, 9.0, 0.35) // high frequency, low contrast
            };
            Texture {
                fx: rng.uniform_in(fmin, fmax) * if rng.uniform() < 0.5 { -1.0 } else { 1.0 },
                fy: rng.uniform_in(fmin, fmax),
                phase: rng.uniform_in(0.0, std::f32::consts::TAU),
                amp: amp * rng.uniform_in(0.7, 1.3),
                color: [
                    rng.uniform_in(-1.0, 1.0),
                    rng.uniform_in(-1.0, 1.0),
                    rng.uniform_in(-1.0, 1.0),
                ],
            }
        })
        .collect()
}

/// Generate (train, test) datasets from a spec — fully deterministic.
pub fn generate(spec: &SynthSpec) -> (Dataset, Dataset) {
    let mut rng = Rng::new(spec.seed);
    let n_coarse = 3;
    let n_fine = 4;

    // Per-class texture banks; with probability `confusability` a class
    // inherits its coarse bank from the previous class, leaving only the
    // fine (quantization-fragile) textures to separate the pair.
    let mut class_textures: Vec<Vec<Texture>> = Vec::with_capacity(spec.classes);
    for c in 0..spec.classes {
        let coarse = if c > 0 && rng.uniform() < spec.confusability as f64 {
            class_textures[c - 1][..n_coarse].to_vec()
        } else {
            texture_bank(&mut rng, true, n_coarse)
        };
        let mut bank = coarse;
        bank.extend(texture_bank(&mut rng, false, n_fine));
        class_textures.push(bank);
    }

    let make = |n: usize, rng: &mut Rng| -> Dataset {
        let hw = spec.hw;
        let sz = hw * hw * spec.channels;
        let mut images = vec![0f32; n * sz];
        let mut labels = vec![0i32; n];
        for i in 0..n {
            let class = i % spec.classes; // balanced
            labels[i] = class as i32;
            let dx = rng.below(hw);
            let dy = rng.below(hw);
            let flip = rng.uniform() < 0.5;
            let brightness = rng.uniform_in(0.85, 1.15);
            let img = &mut images[i * sz..(i + 1) * sz];
            for yy in 0..hw {
                for xx in 0..hw {
                    // cyclic shift + optional horizontal flip
                    let sx = if flip { hw - 1 - xx } else { xx };
                    let u = ((sx + dx) % hw) as f32 / hw as f32;
                    let v = ((yy + dy) % hw) as f32 / hw as f32;
                    for t in &class_textures[class] {
                        let val = t.amp
                            * (std::f32::consts::TAU * (t.fx * u + t.fy * v) + t.phase).sin();
                        for ch in 0..spec.channels {
                            img[(yy * hw + xx) * spec.channels + ch] +=
                                brightness * val * t.color[ch % 3];
                        }
                    }
                }
            }
            for px in img.iter_mut() {
                *px += spec.noise * rng.normal();
            }
        }
        Dataset {
            hw,
            channels: spec.channels,
            classes: spec.classes,
            images,
            labels,
        }
    };

    let train = make(spec.n_train, &mut rng);
    let test = make(spec.n_test, &mut rng);
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_balanced() {
        let spec = SynthSpec::tiny(9);
        let (a, _) = generate(&spec);
        let (b, _) = generate(&spec);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        let mut counts = vec![0usize; spec.classes];
        for &l in &a.labels {
            counts[l as usize] += 1;
        }
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(max - min <= 1, "balanced classes: {counts:?}");
    }

    #[test]
    fn pixels_are_normalized_scale(// roughly zero-mean, O(1) std
    ) {
        let (train, _) = generate(&SynthSpec::tiny(3));
        let n = train.images.len() as f64;
        let mean: f64 = train.images.iter().map(|&x| x as f64).sum::<f64>() / n;
        let var: f64 =
            train.images.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.3, "mean {mean}");
        assert!(var > 0.05 && var < 10.0, "var {var}");
    }

    #[test]
    fn split_is_disjoint_partition_and_stratified() {
        let (train, _) = generate(&SynthSpec::tiny(5));
        let (a, b) = train.split(0.5, 1);
        assert_eq!(a.len() + b.len(), train.len());
        let mut counts = vec![0usize; a.classes];
        for &l in &a.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c > 0), "stratified: {counts:?}");
    }

    #[test]
    fn fingerprint_tracks_content_and_geometry() {
        let (a, _) = generate(&SynthSpec::tiny(5));
        let (b, _) = generate(&SynthSpec::tiny(5));
        assert_eq!(a.fingerprint(), b.fingerprint(), "deterministic");
        let (c, _) = generate(&SynthSpec::tiny(6));
        assert_ne!(a.fingerprint(), c.fingerprint(), "content-sensitive");
        let mut d = a.clone();
        d.labels[0] ^= 1;
        assert_ne!(a.fingerprint(), d.fingerprint(), "label-sensitive");
    }

    #[test]
    fn gather_shapes() {
        let (train, _) = generate(&SynthSpec::tiny(5));
        let (x, y) = train.gather(&[0, 5, 9]);
        assert_eq!(x.shape(), &[3, 16, 16, 3]);
        assert_eq!(y.shape(), &[3]);
    }
}
