//! Fig. 3 demo: dump the aggregated quantization function (Eq. 6) as
//! CSV + a terminal sparkline, showing how EBS interpolates between
//! candidate step functions as the strengths move.
//!
//!   cargo run --release --example fig3_quant_function

fn main() -> anyhow::Result<()> {
    let out = std::path::Path::new("runs/reports");
    ebs::report::fig3::run(out, 200)?;

    // Terminal rendering of the r=[0,0] vs r=[-1,1] mixtures.
    let csv = std::fs::read_to_string(out.join("fig3.csv"))?;
    let rows: Vec<Vec<f64>> = csv
        .lines()
        .skip(1)
        .map(|l| l.split(',').map(|v| v.parse().unwrap_or(0.0)).collect())
        .collect();
    let glyphs = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    for (label, col) in [("mix r=[0,0] over B={2,3}", 3usize), ("mix r=[-1,1]", 4)] {
        let line: String = rows
            .iter()
            .step_by(2)
            .map(|r| {
                let v = ((r[col] + 1.0) / 2.0 * (glyphs.len() - 1) as f64).round() as usize;
                glyphs[v.min(glyphs.len() - 1)]
            })
            .collect();
        println!("{label:<26} |{line}|");
    }
    println!("(full curves in runs/reports/fig3.csv — plot w vs each column)");
    Ok(())
}
