//! Multi-model registry with atomic hot-swap (DESIGN.md §15).
//!
//! The registry holds N resident [`BdNetwork`]s keyed by model name.
//! Each name maps to a [`ModelEntry`] whose `current` slot holds an
//! `Arc<ResidentModel>` — the unit of swap.  Admission resolves the
//! name to that Arc *once* and the request carries it through queue →
//! batcher → worker, so:
//!
//! * **zero downtime** — [`ModelRegistry::publish`] replaces the slot
//!   under a short lock; no admission ever observes a half-installed
//!   model;
//! * **in-flight safety** — queued requests keep their Arc, so the old
//!   generation's network stays alive until its last request is
//!   answered, then drops;
//! * **bit-identity per generation** — the batcher coalesces only
//!   same-generation requests (queue.rs), so every executed batch runs
//!   wholly on one network and equals a direct `classify_batch` on it.
//!
//! Generations are registry-global and monotonic; per-name counters
//! ([`ModelStats`]) persist across swaps (the swap itself is recorded
//! in `swaps` / the `generation` gauge).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::Result;

use crate::bd::BdNetwork;

use super::telemetry::ModelStats;

/// One immutable published generation of a model: what a request binds
/// to at admission and what a worker executes against.
pub struct ResidentModel {
    /// Registry key (`--model NAME=SOURCE`).
    pub name: String,
    /// Registry-global monotonic swap counter; two generations of the
    /// same name never share it.
    pub generation: u64,
    /// Artifact version label (`deploy_manifest.json`) or
    /// `synthetic:<seed>`.
    pub version: String,
    /// Where the generation came from (artifact dir / synthetic spec).
    pub source: String,
    pub net: Arc<BdNetwork>,
    /// Shared with every other generation of this name.
    pub stats: Arc<ModelStats>,
}

impl ResidentModel {
    /// Floats per image of this generation's network.
    pub fn image_size(&self) -> usize {
        self.net.input_hw * self.net.input_hw * self.net.input_ch
    }
}

/// A model name's slot: stable stats + the swappable current generation.
struct ModelEntry {
    name: String,
    stats: Arc<ModelStats>,
    current: Mutex<Arc<ResidentModel>>,
}

/// A freshly loaded (not yet published) model — what a
/// [`super::ModelLoader`] returns.
pub struct LoadedModel {
    pub version: String,
    pub net: BdNetwork,
}

/// Why a model name failed to resolve at admission.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ResolveError {
    /// The name is not registered.
    Unknown(String),
    /// Empty name with several resident models — no implicit default.
    Ambiguous(Vec<String>),
}

impl std::fmt::Display for ResolveError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ResolveError::Unknown(name) => write!(f, "unknown model '{name}'"),
            ResolveError::Ambiguous(names) => write!(
                f,
                "several models resident ({}); requests must name one",
                names.join(", ")
            ),
        }
    }
}

/// The registry: entry list behind an RwLock (reads are resolve-heavy,
/// writes are rare publishes).
#[derive(Default)]
pub struct ModelRegistry {
    entries: RwLock<Vec<Arc<ModelEntry>>>,
    next_gen: AtomicU64,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// Install `net` as the current generation of `name`, creating the
    /// entry on first publish.  Returns the new resident handle; its
    /// `generation` strictly exceeds every previously published one.
    pub fn publish(
        &self,
        name: &str,
        version: &str,
        source: &str,
        net: BdNetwork,
    ) -> Arc<ResidentModel> {
        let generation = self.next_gen.fetch_add(1, Ordering::Relaxed) + 1;
        let make = |stats: &Arc<ModelStats>| {
            Arc::new(ResidentModel {
                name: name.to_string(),
                generation,
                version: version.to_string(),
                source: source.to_string(),
                net: Arc::new(net),
                stats: Arc::clone(stats),
            })
        };
        let mut entries = self.entries.write().unwrap();
        if let Some(entry) = entries.iter().find(|e| e.name == name) {
            let resident = make(&entry.stats);
            entry.stats.swaps.fetch_add(1, Ordering::Relaxed);
            entry.stats.generation.store(generation, Ordering::Relaxed);
            *entry.current.lock().unwrap() = Arc::clone(&resident);
            resident
        } else {
            let stats = Arc::new(ModelStats::default());
            stats.generation.store(generation, Ordering::Relaxed);
            let resident = make(&stats);
            entries.push(Arc::new(ModelEntry {
                name: name.to_string(),
                stats,
                current: Mutex::new(Arc::clone(&resident)),
            }));
            resident
        }
    }

    /// Convenience publish of a deterministic synthetic net — the
    /// `--model NAME=synthetic:SEED` path, and what tests and the
    /// bench use to stand up multi-model fleets without artifacts.
    pub fn publish_synthetic(&self, name: &str, seed: u64) -> Arc<ResidentModel> {
        let spec = format!("synthetic:{seed}");
        self.publish(name, &spec, &spec, BdNetwork::synthetic(seed))
    }

    /// Resolve a request's model name to the current generation.  An
    /// empty name is allowed exactly when one model is resident (the
    /// single-model deployment keeps v1's ergonomics).
    pub fn resolve(&self, name: &str) -> Result<Arc<ResidentModel>, ResolveError> {
        let entries = self.entries.read().unwrap();
        let entry = if name.is_empty() {
            match entries.len() {
                1 => &entries[0],
                _ => {
                    return Err(ResolveError::Ambiguous(
                        entries.iter().map(|e| e.name.clone()).collect(),
                    ))
                }
            }
        } else {
            match entries.iter().find(|e| e.name == name) {
                Some(e) => e,
                None => return Err(ResolveError::Unknown(name.to_string())),
            }
        };
        Ok(Arc::clone(&entry.current.lock().unwrap()))
    }

    /// Snapshot of every model's current generation, registration order.
    pub fn models(&self) -> Vec<Arc<ResidentModel>> {
        self.entries
            .read()
            .unwrap()
            .iter()
            .map(|e| Arc::clone(&e.current.lock().unwrap()))
            .collect()
    }

    pub fn len(&self) -> usize {
        self.entries.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_resolve_and_default_rules() {
        let reg = ModelRegistry::new();
        assert!(matches!(reg.resolve(""), Err(ResolveError::Ambiguous(_))), "empty registry");
        let a = reg.publish_synthetic("a", 11);
        assert_eq!(a.generation, 1);
        assert_eq!(reg.resolve("").unwrap().name, "a", "sole model is the default");
        assert_eq!(reg.resolve("a").unwrap().generation, 1);
        reg.publish_synthetic("b", 22);
        match reg.resolve("") {
            Err(ResolveError::Ambiguous(names)) => assert_eq!(names, vec!["a", "b"]),
            other => panic!("two models → no implicit default, got {other:?}"),
        }
        assert!(matches!(reg.resolve("zzz"), Err(ResolveError::Unknown(_))));
    }

    #[test]
    fn swap_bumps_generation_keeps_stats_and_old_arc_survives() {
        let reg = ModelRegistry::new();
        let g1 = reg.publish_synthetic("a", 11);
        g1.stats.admitted.fetch_add(5, Ordering::Relaxed);
        let g2 = reg.publish_synthetic("a", 33);
        assert!(g2.generation > g1.generation, "generations are monotonic");
        assert_eq!(reg.resolve("a").unwrap().generation, g2.generation);
        // Stats survive the swap, and the swap itself is recorded.
        assert_eq!(g2.stats.admitted.load(Ordering::Relaxed), 5);
        assert_eq!(g2.stats.swaps.load(Ordering::Relaxed), 1);
        assert_eq!(g2.stats.generation.load(Ordering::Relaxed), g2.generation);
        // The superseded generation's network is still usable by
        // whoever holds the Arc (in-flight requests).
        let img_sz = g1.image_size();
        let _ = g1.net.classify_batch(&vec![0.5; img_sz], 1);
        assert_eq!(reg.len(), 1, "swap replaces, not appends");
    }

    #[test]
    fn models_snapshot_tracks_currents() {
        let reg = ModelRegistry::new();
        reg.publish_synthetic("a", 1);
        reg.publish_synthetic("b", 2);
        reg.publish_synthetic("a", 3);
        let gens: Vec<(String, u64)> =
            reg.models().iter().map(|m| (m.name.clone(), m.generation)).collect();
        assert_eq!(gens, vec![("a".into(), 3), ("b".into(), 2)]);
    }
}
