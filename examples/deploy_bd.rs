//! Deployment scenario: mixed precision convolution on generic hardware
//! (the paper's §4.3 + Appendix A experiment, standalone).
//!
//!   cargo run --release --example deploy_bd
//!
//! Builds BD conv layers at the paper's Table 4 shapes, verifies the
//! integer path against the fake-quantized float reference, and prints
//! the W1-A1 vs W1-A2 latency table — the ~2× ratio is the reproduced
//! claim.  Also demonstrates the paper-literal two-stage path
//! (materialized P = B_w·B_x, then the stride-(M,K) shift-add kernel).

use ebs::bd::layer::BdConvLayer;
use ebs::bd::reference::conv2d_fakequant;
use ebs::bd::BdMode;
use ebs::report::table4::{layer_latency_ms, paper_layers};
use ebs::util::Rng;

fn main() -> anyhow::Result<()> {
    println!("== Binary Decomposition deployment demo ==\n");

    // 1. Correctness: BD integer path ≡ fake-quant float conv.
    let mut rng = Rng::new(2024);
    let (ci, co, k, hw) = (32usize, 32usize, 3usize, 12usize);
    let wts: Vec<f32> = (0..k * k * ci * co).map(|_| 0.4 * rng.normal()).collect();
    let x: Vec<f32> = (0..hw * hw * ci).map(|_| rng.normal().abs()).collect();
    for (mb, kb) in [(1u32, 1u32), (1, 2), (2, 3), (4, 4)] {
        let mut layer =
            BdConvLayer::new("demo", &wts, ci, co, k, 1, mb, kb, 3.0, None, false)?;
        let (got, _, _) = layer.forward(&x, hw, hw);
        layer.mode = BdMode::TwoStage;
        let (got2, _, _) = layer.forward(&x, hw, hw);
        let (want, _, _) = conv2d_fakequant(&x, hw, hw, ci, &wts, co, k, 1, mb, kb, 3.0);
        let err = got.iter().zip(&want).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
        assert_eq!(got, got2, "fused vs two-stage must be bit-identical");
        println!("W{mb}-A{kb}: max |BD − fakequant| = {err:.2e}  (AND ops: {})", layer.and_ops(hw * hw));
    }

    // 2. Latency: the paper's Table 4 shapes.
    println!("\nlayer latency (median ms), x86-64 POPCNT engine:");
    println!("{:<28} {:>10} {:>10} {:>8}", "shape", "W1-A1", "W1-A2", "ratio");
    for s in paper_layers() {
        let a = layer_latency_ms(&s, 1, 1, 5);
        let b = layer_latency_ms(&s, 1, 2, 5);
        println!(
            "{:<28} {:>10.2} {:>10.2} {:>7.2}x",
            format!("{}x{} {}→{} s{} @{}²", s.k, s.k, s.ci, s.co, s.stride, s.hw),
            a,
            b,
            b / a
        );
    }
    println!("\npaper (ARM Cortex-A53): W1-A2 ≈ 2× W1-A1 — the ratio, not the absolute ms, is the claim.");
    Ok(())
}
