//! Native optimizers — Rust mirror of `python/compile/optim.py`,
//! operating directly on [`StateVec`] leaves so the native backend's
//! state layout stays interchangeable with artifact checkpoints.
//!
//! * Weight phase (Eq. 10): heavy-ball SGD `v' = 0.9·v + (g + wd·mask·p)`,
//!   `p' = p − lr·v'` over every `state/params/*` and `state/alphas/*`
//!   leaf.  The decay mask follows `model.decay_mask`: 1.0 on conv/fc
//!   `w` leaves, 0.0 on BN affine and the fc bias; α leaves are decayed
//!   (python applies `sgd_momentum` to them with the default all-ones
//!   mask).
//! * Arch phase (Eq. 9): Adam(β₁=0.9, β₂=0.999, ε=1e-8) with bias
//!   correction over `state/arch/{r,s}/*`, moments in
//!   `state/opt/adam/{m,v}/...` and the shared f32 step counter
//!   `state/opt/adam/t`.
//!
//! Leaves without a gradient entry still receive the weight-decay +
//! momentum update (their gradient is zero), exactly like `jax.grad`
//! returning zero cotangents.

use std::collections::HashMap;

use anyhow::Result;

use crate::runtime::StateVec;

pub const MOMENTUM: f32 = 0.9;
pub const ADAM_B1: f32 = 0.9;
pub const ADAM_B2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;

/// `model.decay_mask` parity: decay conv/fc weights, skip BN affine and
/// biases; every α is decayed.
fn decay_factor(path: &str) -> f32 {
    if let Some(rest) = path.strip_prefix("state/params/") {
        let mut it = rest.rsplitn(2, '/');
        let leaf = it.next().unwrap_or("");
        let group = it.next().unwrap_or("");
        if !group.starts_with("bn_") && leaf == "w" {
            return 1.0;
        }
        return 0.0;
    }
    if path.starts_with("state/alphas/") {
        return 1.0;
    }
    0.0
}

/// SGD-momentum update of all `state/params/*` + `state/alphas/*`
/// leaves.  `grads` maps state paths to dense gradients (missing ⇒ 0).
pub fn sgd_momentum_step(
    state: &mut StateVec,
    grads: &HashMap<String, Vec<f32>>,
    lr: f32,
    weight_decay: f32,
) -> Result<()> {
    let paths: Vec<String> = state
        .spec
        .iter()
        .filter(|l| l.path.starts_with("state/params/") || l.path.starts_with("state/alphas/"))
        .map(|l| l.path.clone())
        .collect();
    for path in paths {
        let vel_path = if let Some(rest) = path.strip_prefix("state/params/") {
            format!("state/opt/mom/params/{rest}")
        } else {
            let rest = path.strip_prefix("state/alphas/").unwrap();
            format!("state/opt/mom/alphas/{rest}")
        };
        let mask = decay_factor(&path);
        let g = grads.get(&path);
        let vi = state.idx(&vel_path)?;
        let pi = state.idx(&path)?;
        // split-borrow the two leaves
        let (a, b) = if vi < pi {
            let (lo, hi) = state.tensors.split_at_mut(pi);
            (&mut lo[vi], &mut hi[0])
        } else {
            let (lo, hi) = state.tensors.split_at_mut(vi);
            (&mut hi[0], &mut lo[pi])
        };
        let vel = a.as_f32_mut()?;
        let p = b.as_f32_mut()?;
        for j in 0..p.len() {
            let gj = g.map(|v| v[j]).unwrap_or(0.0) + weight_decay * mask * p[j];
            let v_new = MOMENTUM * vel[j] + gj;
            vel[j] = v_new;
            p[j] -= lr * v_new;
        }
    }
    Ok(())
}

/// Adam update of the architecture strengths.  `grads` maps
/// `state/arch/{r,s}/<name>` paths to gradients; leaves without an
/// entry get a zero gradient (their moments still decay).
pub fn adam_step(
    state: &mut StateVec,
    grads: &HashMap<String, Vec<f32>>,
    lr: f32,
) -> Result<()> {
    let t_new = {
        let t = state.get_mut("state/opt/adam/t")?.as_f32_mut()?;
        t[0] += 1.0;
        t[0]
    };
    let bc1 = 1.0 - ADAM_B1.powf(t_new);
    let bc2 = 1.0 - ADAM_B2.powf(t_new);
    let paths: Vec<String> = state
        .spec
        .iter()
        .filter(|l| l.path.starts_with("state/arch/"))
        .map(|l| l.path.clone())
        .collect();
    for path in paths {
        let rest = path.strip_prefix("state/arch/").unwrap().to_string();
        let m_path = format!("state/opt/adam/m/{rest}");
        let v_path = format!("state/opt/adam/v/{rest}");
        let g = grads.get(&path).cloned();
        let n = state.get(&path)?.len();
        let g = g.unwrap_or_else(|| vec![0.0; n]);
        // three disjoint leaves: update moments first, then the param.
        let (m_new, v_new): (Vec<f32>, Vec<f32>) = {
            let m = state.get_mut(&m_path)?.as_f32_mut()?;
            let m_new: Vec<f32> = m
                .iter()
                .zip(&g)
                .map(|(&mv, &gv)| ADAM_B1 * mv + (1.0 - ADAM_B1) * gv)
                .collect();
            m.copy_from_slice(&m_new);
            let v = state.get_mut(&v_path)?.as_f32_mut()?;
            let v_new: Vec<f32> = v
                .iter()
                .zip(&g)
                .map(|(&vv, &gv)| ADAM_B2 * vv + (1.0 - ADAM_B2) * gv * gv)
                .collect();
            v.copy_from_slice(&v_new);
            (m_new, v_new)
        };
        let p = state.get_mut(&path)?.as_f32_mut()?;
        for j in 0..p.len() {
            let m_hat = m_new[j] / bc1;
            let v_hat = v_new[j] / bc2;
            p[j] -= lr * m_hat / (v_hat.sqrt() + ADAM_EPS);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decay_mask_parity() {
        assert_eq!(decay_factor("state/params/s0b0c1/w"), 1.0);
        assert_eq!(decay_factor("state/params/stem/w"), 1.0);
        assert_eq!(decay_factor("state/params/fc/w"), 1.0);
        assert_eq!(decay_factor("state/params/fc/b"), 0.0);
        assert_eq!(decay_factor("state/params/bn_s0b0c1/gamma"), 0.0);
        assert_eq!(decay_factor("state/params/bn_stem/beta"), 0.0);
        assert_eq!(decay_factor("state/alphas/s0b0c1"), 1.0);
        assert_eq!(decay_factor("state/arch/r/s0b0c1"), 0.0);
    }
}
