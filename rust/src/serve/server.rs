//! Serve front-end (DESIGN.md §13): a TCP accept loop (or a single
//! stdin/stdout session) feeding the queue → micro-batcher → worker
//! pipeline, with graceful drain on shutdown.
//!
//! Threading: one reader thread per connection decodes frames and
//! submits classify requests; completions write the response frame
//! straight from the worker under the connection's write mutex (no
//! per-connection writer thread — a slow client briefly blocks one
//! worker, acceptable at this scale and it makes the drain trivially
//! correct: once the pool joins, every response has been written).
//!
//! Shutdown protocol: on a shutdown request the session acks, closes
//! the queue (no new admissions anywhere — concurrent submissions get
//! `ERR_SHUTTING_DOWN` frames), and flips the accept loop's flag; the
//! front-end then joins the worker pool, which by the queue's
//! drain-on-close contract answers every admitted request first.
//! EOF on stdin (stdio mode) triggers the same drain.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::bd::BdNetwork;

use super::protocol::{
    self, Request, Response, ERR_BAD_REQUEST, ERR_OVERLOADED, ERR_SHUTTING_DOWN,
};
use super::{ServeCfg, ServeCore, ServeHandle, SubmitError};

/// A bound-but-not-yet-serving TCP front-end (bind is separate from
/// run so callers can learn the ephemeral port before serving).
pub struct Server {
    listener: TcpListener,
    handle: ServeHandle,
    shutdown: Arc<AtomicBool>,
}

impl Server {
    /// Bind `cfg.addr` and spawn the worker pool; serving starts at
    /// [`Server::run`].
    pub fn bind(net: BdNetwork, cfg: ServeCfg) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding serve address {}", cfg.addr))?;
        let handle = ServeHandle::start(net, cfg);
        Ok(Server { listener, handle, shutdown: Arc::new(AtomicBool::new(false)) })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept-and-serve until a shutdown request arrives, then drain
    /// and return.  Prints `serving on <addr>` to stdout first (the CI
    /// smoke driver parses it to find the ephemeral port).
    pub fn run(self) -> Result<()> {
        let Server { listener, handle, shutdown } = self;
        let addr = listener.local_addr()?;
        println!("serving on {addr}");
        std::io::stdout().flush().ok();
        listener.set_nonblocking(true).context("nonblocking accept loop")?;
        while !shutdown.load(Ordering::Acquire) {
            match listener.accept() {
                Ok((stream, peer)) => {
                    stream.set_nonblocking(false).ok();
                    stream.set_nodelay(true).ok();
                    let reader = match stream.try_clone() {
                        Ok(r) => r,
                        Err(e) => {
                            eprintln!("[serve] dropping {peer}: {e}");
                            continue;
                        }
                    };
                    let core = Arc::clone(&handle.core);
                    let writer = Arc::new(Mutex::new(stream));
                    let flag = Arc::clone(&shutdown);
                    std::thread::spawn(move || {
                        if let Err(e) = handle_session(&core, reader, &writer, &flag) {
                            eprintln!("[serve] session {peer}: {e:#}");
                        }
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    eprintln!("[serve] accept error: {e}");
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
        }
        let stats = Arc::clone(&handle.core.stats);
        let net = Arc::clone(&handle.core.net);
        handle.shutdown(); // drain: every admitted request is answered
        eprintln!("[serve] drained; final stats: {}", stats.to_json(&net));
        Ok(())
    }
}

/// Single-session mode over stdin/stdout (`ebs serve --stdin`): same
/// frames, no sockets.  EOF or a shutdown request drains and returns.
pub fn run_stdio(net: BdNetwork, cfg: ServeCfg) -> Result<()> {
    let handle = ServeHandle::start(net, cfg);
    let shutdown = Arc::new(AtomicBool::new(false));
    let writer = Arc::new(Mutex::new(std::io::stdout()));
    let result = handle_session(&handle.core, std::io::stdin().lock(), &writer, &shutdown);
    let stats = Arc::clone(&handle.core.stats);
    let net = Arc::clone(&handle.core.net);
    handle.shutdown();
    writer.lock().unwrap().flush().ok();
    eprintln!("[serve] drained; final stats: {}", stats.to_json(&net));
    result
}

/// Decode-dispatch loop for one connection.  Returns on clean EOF, a
/// transport error, or a shutdown request (after acking + flipping
/// `shutdown`).
pub fn handle_session<R: Read, W: Write + Send + 'static>(
    core: &Arc<ServeCore>,
    mut reader: R,
    writer: &Arc<Mutex<W>>,
    shutdown: &AtomicBool,
) -> Result<()> {
    let img_sz = core.image_size();
    loop {
        let Some(payload) = protocol::read_frame(&mut reader)? else {
            return Ok(()); // client hung up between frames
        };
        let req = match protocol::decode_request(&payload) {
            Ok(r) => r,
            Err(e) => {
                send(writer, &Response::Error { id: 0, code: ERR_BAD_REQUEST, msg: format!("{e:#}") })?;
                continue;
            }
        };
        match req {
            Request::Classify { id, count, images } => {
                let count = count as usize;
                if count == 0 || images.len() != count * img_sz {
                    let msg = format!(
                        "classify request {id}: {} floats for count {count} (image size {img_sz})",
                        images.len()
                    );
                    send(writer, &Response::Error { id, code: ERR_BAD_REQUEST, msg })?;
                    continue;
                }
                let w = Arc::clone(writer);
                let submitted = core.submit_with(
                    images,
                    count,
                    Box::new(move |preds| {
                        let labels = preds.iter().map(|&p| p as u32).collect();
                        let _ = send(&w, &Response::Classify { id, labels });
                    }),
                );
                if let Err(e) = submitted {
                    let code = match e {
                        SubmitError::Overloaded => ERR_OVERLOADED,
                        SubmitError::ShuttingDown => ERR_SHUTTING_DOWN,
                    };
                    send(writer, &Response::Error { id, code, msg: e.to_string() })?;
                }
            }
            Request::Stats { id } => {
                let json = core.stats.to_json(&core.net).to_string();
                send(writer, &Response::Stats { id, json })?;
            }
            Request::Shutdown { id } => {
                send(writer, &Response::ShutdownAck { id })?;
                core.queue.close();
                shutdown.store(true, Ordering::Release);
                return Ok(());
            }
        }
    }
}

fn send<W: Write>(writer: &Arc<Mutex<W>>, resp: &Response) -> std::io::Result<()> {
    let frame = protocol::encode_response(resp);
    let mut g = writer.lock().unwrap();
    g.write_all(&frame)?;
    g.flush()
}
