//! Versioned deployment artifacts — the one load/store path shared by
//! `ebs deploy` (producer) and `ebs serve` (consumer); DESIGN.md §15.
//!
//! A deployment artifact is a directory holding the retrained
//! checkpoint (`retrained.ckpt`) and the searched bitwidth selection
//! (`selection.json`), sealed by a `deploy_manifest.json` that records
//! the architecture name, a version label, per-file sha256 checksums,
//! and the selection metadata (per-layer bitwidths + means) for
//! fleet-side introspection without parsing the checkpoint.
//!
//! [`DeploymentArtifact::write`] hashes the files and emits the
//! manifest; [`DeploymentArtifact::load`] re-verifies every checksum
//! before anything touches the checkpoint bytes, failing with a typed
//! [`ArtifactError`] (corrupt manifest / checksum mismatch / format
//! version skew) so the serving tier can refuse a torn or tampered
//! deployment *before* swapping it under live traffic.

use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::coordinator::Selection;
use crate::runtime::{Manifest, StateVec};
use crate::util::json::{parse, Json};
use crate::util::sha256;

use super::layer::BdMode;
use super::network::BdNetwork;

/// Manifest filename inside an artifact directory.
pub const MANIFEST_FILE: &str = "deploy_manifest.json";

/// Artifact format version; bump on incompatible manifest changes.
pub const ARTIFACT_FORMAT: u64 = 1;

/// Checkpoint filename (written by the pipeline, sealed by deploy).
pub const CKPT_FILE: &str = "retrained.ckpt";

/// Selection filename (written by search/pipeline, sealed by deploy).
pub const SELECTION_FILE: &str = "selection.json";

/// Why an artifact was rejected.  Typed so callers (the serve
/// registry, tests) can distinguish corruption from skew without
/// string-matching.
#[derive(Debug)]
pub enum ArtifactError {
    /// `deploy_manifest.json` is absent — the directory was never
    /// sealed by `ebs deploy`.
    MissingManifest(PathBuf),
    /// The manifest exists but does not parse / lacks required fields.
    CorruptManifest { path: PathBuf, cause: String },
    /// The manifest's `artifact_format` is not one this binary reads.
    VersionSkew { found: u64, supported: u64 },
    /// A file listed in the manifest is missing or unreadable.
    MissingFile { file: String, cause: String },
    /// A file's sha256 does not match the sealed checksum.
    ChecksumMismatch { file: String, want: String, got: String },
}

impl fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArtifactError::MissingManifest(p) => {
                write!(f, "no {MANIFEST_FILE} in {} (run `ebs deploy` to seal it)", p.display())
            }
            ArtifactError::CorruptManifest { path, cause } => {
                write!(f, "corrupt {}: {cause}", path.display())
            }
            ArtifactError::VersionSkew { found, supported } => write!(
                f,
                "artifact format {found} is not supported (this binary reads format {supported})"
            ),
            ArtifactError::MissingFile { file, cause } => {
                write!(f, "artifact file '{file}' unreadable: {cause}")
            }
            ArtifactError::ChecksumMismatch { file, want, got } => write!(
                f,
                "artifact file '{file}' checksum mismatch: manifest says sha256 {want}, file is {got}"
            ),
        }
    }
}

impl std::error::Error for ArtifactError {}

/// A verified deployment artifact: manifest metadata + the directory
/// the (checksum-clean) files live in.
#[derive(Debug, Clone)]
pub struct DeploymentArtifact {
    pub dir: PathBuf,
    /// Architecture name (engine/model-registry key, e.g. `resnet8_tiny`).
    pub model: String,
    /// Version label; defaults to a checksum-derived tag on write.
    pub version: String,
    pub selection: Selection,
    /// `(relative file, sha256 hex)` in manifest order.
    pub files: Vec<(String, String)>,
}

impl DeploymentArtifact {
    /// Seal `dir` (which must already contain [`CKPT_FILE`] and
    /// [`SELECTION_FILE`]) into a versioned artifact: hash the files
    /// and write [`MANIFEST_FILE`].  `version` may be empty, in which
    /// case a content-derived label (`sha-<12 hex of the checkpoint>`)
    /// is used, so re-deploying identical bytes yields an identical
    /// version string.
    pub fn write(dir: &Path, model: &str, version: &str) -> Result<DeploymentArtifact> {
        let mut files = Vec::new();
        for name in [CKPT_FILE, SELECTION_FILE] {
            let digest = sha256::file_digest(&dir.join(name))
                .with_context(|| format!("hashing {} in {}", name, dir.display()))?;
            files.push((name.to_string(), digest));
        }
        let selection = Selection::load(&dir.join(SELECTION_FILE))?;
        let version = if version.is_empty() {
            format!("sha-{}", &files[0].1[..12])
        } else {
            version.to_string()
        };
        let (mw, mx) = selection.mean_bits();
        let doc = Json::Obj(vec![
            ("artifact_format".into(), Json::Num(ARTIFACT_FORMAT as f64)),
            ("model".into(), Json::Str(model.to_string())),
            ("version".into(), Json::Str(version.clone())),
            ("created_by".into(), Json::Str(format!("ebs {}", env!("CARGO_PKG_VERSION")))),
            ("mean_w_bits".into(), Json::Num(mw)),
            ("mean_x_bits".into(), Json::Num(mx)),
            ("selection".into(), selection.to_json()),
            (
                "files".into(),
                Json::Obj(
                    files.iter().map(|(n, d)| (n.clone(), Json::Str(d.clone()))).collect(),
                ),
            ),
        ]);
        std::fs::write(dir.join(MANIFEST_FILE), doc.to_string())
            .with_context(|| format!("writing {} in {}", MANIFEST_FILE, dir.display()))?;
        Ok(DeploymentArtifact {
            dir: dir.to_path_buf(),
            model: model.to_string(),
            version,
            selection,
            files,
        })
    }

    /// Load and verify an artifact: parse the manifest, check the
    /// format version, then re-hash every listed file against its
    /// sealed checksum.  Nothing downstream (checkpoint decode, net
    /// assembly) runs unless every byte verifies.
    pub fn load(dir: &Path) -> std::result::Result<DeploymentArtifact, ArtifactError> {
        let mpath = dir.join(MANIFEST_FILE);
        let text = match std::fs::read_to_string(&mpath) {
            Ok(t) => t,
            Err(_) => return Err(ArtifactError::MissingManifest(dir.to_path_buf())),
        };
        let manifest = parse_manifest(&text, &mpath)?;
        let mut files = Vec::with_capacity(manifest.files.len());
        for (name, want) in manifest.files {
            let got = sha256::file_digest(&dir.join(&name)).map_err(|e| {
                ArtifactError::MissingFile { file: name.clone(), cause: e.to_string() }
            })?;
            if got != want {
                return Err(ArtifactError::ChecksumMismatch { file: name, want, got });
            }
            files.push((name, want));
        }
        Ok(DeploymentArtifact {
            dir: dir.to_path_buf(),
            model: manifest.model,
            version: manifest.version,
            selection: manifest.selection,
            files,
        })
    }

    /// Assemble the deployable [`BdNetwork`] from the verified files.
    /// `manifest` is the runtime manifest of [`Self::model`] (callers
    /// open the engine; this module stays transport- and backend-free).
    pub fn build_network(&self, manifest: &Manifest, mode: BdMode) -> Result<BdNetwork> {
        let state = StateVec::load(&self.dir.join(CKPT_FILE), &manifest.state_spec)
            .with_context(|| format!("loading {} from {}", CKPT_FILE, self.dir.display()))?;
        BdNetwork::from_state(manifest, &state, &self.selection, mode)
    }
}

/// Manifest metadata as parsed (file checksums not yet verified).
#[derive(Debug, Clone)]
pub struct ParsedManifest {
    pub model: String,
    pub version: String,
    pub selection: Selection,
    /// `(relative file, sealed sha256 hex)` in manifest order.
    pub files: Vec<(String, String)>,
}

/// Parse and validate manifest *text* — the pure half of
/// [`DeploymentArtifact::load`], split out so the fuzz harness can
/// drive it with arbitrary bytes and no filesystem.  `mpath` is only
/// used to attribute [`ArtifactError::CorruptManifest`].
///
/// File names come from an untrusted manifest and are later joined to
/// the artifact directory, so anything that could escape it (path
/// separators, `..` components, absolute paths, empty names) is
/// rejected here as corruption rather than handed to the filesystem.
pub fn parse_manifest(
    text: &str,
    mpath: &Path,
) -> std::result::Result<ParsedManifest, ArtifactError> {
    let corrupt =
        |cause: String| ArtifactError::CorruptManifest { path: mpath.to_path_buf(), cause };
    let doc = parse(text).map_err(|e| corrupt(format!("{e:#}")))?;
    let format = doc
        .req("artifact_format")
        .and_then(|v| v.as_u64())
        .map_err(|e| corrupt(format!("{e:#}")))?;
    if format != ARTIFACT_FORMAT {
        return Err(ArtifactError::VersionSkew { found: format, supported: ARTIFACT_FORMAT });
    }
    let str_field = |key: &str| -> std::result::Result<String, ArtifactError> {
        doc.req(key)
            .and_then(|v| v.as_str().map(str::to_string))
            .map_err(|e| corrupt(format!("{e:#}")))
    };
    let model = str_field("model")?;
    let version = str_field("version")?;
    let sel_json = doc.req("selection").map_err(|e| corrupt(format!("{e:#}")))?;
    let sel_bits = |key: &str| -> std::result::Result<Vec<u32>, ArtifactError> {
        sel_json
            .req(key)
            .and_then(|v| v.as_arr())
            .map_err(|e| corrupt(format!("{e:#}")))?
            .iter()
            .map(|v| v.as_usize().map(|b| b as u32).map_err(|e| corrupt(format!("{e:#}"))))
            .collect()
    };
    let selection = Selection { w_bits: sel_bits("w_bits")?, x_bits: sel_bits("x_bits")? };
    let files_obj = doc
        .req("files")
        .and_then(|v| v.as_obj().map(|o| o.to_vec()))
        .map_err(|e| corrupt(format!("{e:#}")))?;
    let mut files = Vec::with_capacity(files_obj.len());
    for (name, v) in &files_obj {
        if name.is_empty()
            || name.contains('/')
            || name.contains('\\')
            || name.split('.').all(str::is_empty)
        {
            return Err(corrupt(format!("file name '{name}' is not a plain relative name")));
        }
        let want = v
            .as_str()
            .map_err(|e| corrupt(format!("checksum for '{name}': {e:#}")))?
            .to_string();
        files.push((name.clone(), want));
    }
    Ok(ParsedManifest { model, version, selection, files })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ebs_artifact_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// Write a minimal artifact dir: a junk checkpoint (checksums do
    /// not care about content) and a real selection.json.
    fn seed_dir(tag: &str) -> PathBuf {
        let d = scratch_dir(tag);
        std::fs::write(d.join(CKPT_FILE), b"not-a-real-checkpoint").unwrap();
        Selection { w_bits: vec![2, 3], x_bits: vec![4, 2] }
            .save(&d.join(SELECTION_FILE))
            .unwrap();
        d
    }

    #[test]
    fn write_then_load_roundtrips_and_verifies() {
        let d = seed_dir("roundtrip");
        let written = DeploymentArtifact::write(&d, "resnet8_tiny", "").unwrap();
        assert!(written.version.starts_with("sha-"), "content-derived label: {}", written.version);
        let loaded = DeploymentArtifact::load(&d).unwrap();
        assert_eq!(loaded.model, "resnet8_tiny");
        assert_eq!(loaded.version, written.version);
        assert_eq!(loaded.selection.w_bits, vec![2, 3]);
        assert_eq!(loaded.files.len(), 2);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn tampered_file_is_rejected_with_checksum_mismatch() {
        let d = seed_dir("tamper");
        DeploymentArtifact::write(&d, "m", "v1").unwrap();
        std::fs::write(d.join(CKPT_FILE), b"tampered-after-sealing").unwrap();
        match DeploymentArtifact::load(&d) {
            Err(ArtifactError::ChecksumMismatch { file, want, got }) => {
                assert_eq!(file, CKPT_FILE);
                assert_ne!(want, got);
            }
            other => panic!("tampered checkpoint must fail checksum, got {other:?}"),
        }
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn corrupt_manifest_and_version_skew_are_typed() {
        let d = seed_dir("corrupt");
        std::fs::write(d.join(MANIFEST_FILE), b"{ not json").unwrap();
        assert!(matches!(
            DeploymentArtifact::load(&d),
            Err(ArtifactError::CorruptManifest { .. })
        ));
        std::fs::write(
            d.join(MANIFEST_FILE),
            r#"{"artifact_format": 999, "model": "m", "version": "v"}"#,
        )
        .unwrap();
        match DeploymentArtifact::load(&d) {
            Err(ArtifactError::VersionSkew { found, supported }) => {
                assert_eq!((found, supported), (999, ARTIFACT_FORMAT));
            }
            other => panic!("future format must be refused, got {other:?}"),
        }
        std::fs::remove_dir_all(&d).ok();
    }

    /// Fuzz regression: manifest `files` keys are attacker-controlled
    /// and get joined to the artifact dir — names that could escape it
    /// must be rejected as corruption before any filesystem access.
    #[test]
    fn traversal_file_names_in_manifest_are_rejected() {
        for name in ["../secret", "/etc/passwd", "a/b", "a\\b", "..", ".", ""] {
            let text = format!(
                r#"{{"artifact_format":1,"model":"m","version":"v","selection":{{"w_bits":[2],"x_bits":[2]}},"files":{{"{}":"00"}}}}"#,
                name.replace('\\', "\\\\")
            );
            match parse_manifest(&text, Path::new("test_manifest")) {
                Err(ArtifactError::CorruptManifest { cause, .. }) => {
                    assert!(
                        cause.contains("not a plain relative name"),
                        "name {name:?}: {cause}"
                    );
                }
                other => panic!("hostile file name {name:?} must be rejected, got {other:?}"),
            }
        }
        // A legitimate name still parses.
        let ok = parse_manifest(
            r#"{"artifact_format":1,"model":"m","version":"v","selection":{"w_bits":[2],"x_bits":[2]},"files":{"retrained.ckpt":"00"}}"#,
            Path::new("test_manifest"),
        )
        .unwrap();
        assert_eq!(ok.files, vec![("retrained.ckpt".to_string(), "00".to_string())]);
    }

    #[test]
    fn unsealed_dir_reports_missing_manifest() {
        let d = seed_dir("unsealed");
        assert!(matches!(
            DeploymentArtifact::load(&d),
            Err(ArtifactError::MissingManifest(_))
        ));
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn missing_listed_file_is_typed() {
        let d = seed_dir("missing");
        DeploymentArtifact::write(&d, "m", "v1").unwrap();
        std::fs::remove_file(d.join(SELECTION_FILE)).unwrap();
        assert!(matches!(
            DeploymentArtifact::load(&d),
            Err(ArtifactError::MissingFile { .. })
        ));
        std::fs::remove_dir_all(&d).ok();
    }
}
