//! Rust-side quantizers — Eq. 1a-1c replicated exactly (third
//! implementation after `ref.py` and the Pallas kernels; cross-tested).
//!
//! The BD deployment engine works on raw integer codes; the affine maps
//! back to real values are:
//!   weights:      w = s_w · c_w + z_w,  s_w = 2/(2^M − 1),  z_w = −1
//!   activations:  x = s_x · c_x,        s_x = α/(2^K − 1)
//!
//! Rounding is *half up* (`floor(v + 0.5)`), matching the paper's §3 and
//! `ref.round_half_up` — NOT Rust's `f32::round` (half away from zero),
//! which differs for negative halves that can occur after tanh
//! normalization noise.

/// Round half up, identical to `ref.round_half_up`.
#[inline]
pub fn round_half_up(v: f32) -> f32 {
    (v + 0.5).floor()
}

/// Weight quantization result: integer codes + affine decode parameters.
#[derive(Debug, Clone)]
pub struct QuantWeights {
    /// Codes in 0..2^bits, flattened in the caller's layout.
    pub codes: Vec<u8>,
    pub bits: u32,
    pub scale: f32, // s_w
    pub zero: f32,  // z_w (−1)
}

/// Eq. 1a: tanh-normalize to [0,1], quantize to `bits`, return codes.
pub fn quantize_weights(w: &[f32], bits: u32) -> QuantWeights {
    let levels = ((1u32 << bits) - 1) as f32;
    let mut max_abs = 0f32;
    let tanhs: Vec<f32> = w
        .iter()
        .map(|&v| {
            let t = v.tanh();
            max_abs = max_abs.max(t.abs());
            t
        })
        .collect();
    let denom = 2.0 * max_abs.max(f32::MIN_POSITIVE);
    let codes = tanhs
        .iter()
        .map(|&t| {
            let norm = t / denom + 0.5;
            round_half_up(norm * levels).clamp(0.0, levels) as u8
        })
        .collect();
    QuantWeights { codes, bits, scale: 2.0 / levels, zero: -1.0 }
}

/// Dequantized weight value for code `c`.
#[inline]
pub fn decode_weight(q: &QuantWeights, c: u8) -> f32 {
    q.scale * c as f32 + q.zero
}

/// Eq. 1b: clip to [0, α], quantize to `bits`; returns codes into `out`.
/// The decode scale is `alpha / (2^bits − 1)`.
///
/// Edge case: `alpha <= 0` (a collapsed or still-uninitialized PACT
/// clip) would divide by zero — `0/0 → NaN` codes at α = 0, and a
/// panicking `clamp(0, α)` for α < 0.  The clip window is empty in both
/// cases, so every activation maps to code 0 and the scale is 0.0
/// (decode of every code is exactly 0); regression-tested in
/// `tests/props.rs`.
pub fn quantize_acts(x: &[f32], alpha: f32, bits: u32, out: &mut [u8]) -> f32 {
    let levels = ((1u32 << bits) - 1) as f32;
    if alpha <= 0.0 {
        out.fill(0);
        return 0.0;
    }
    for (o, &v) in out.iter_mut().zip(x) {
        let clipped = v.clamp(0.0, alpha);
        *o = round_half_up(clipped / alpha * levels).clamp(0.0, levels) as u8;
    }
    alpha / levels
}

/// Float fake-quantized weights (what the HLO graphs see) — used by the
/// parity tests to compare the code path against the training path.
pub fn fake_quant_weights(w: &[f32], bits: u32) -> Vec<f32> {
    let q = quantize_weights(w, bits);
    q.codes.iter().map(|&c| decode_weight(&q, c)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_half_up_ties() {
        assert_eq!(round_half_up(0.5), 1.0);
        assert_eq!(round_half_up(1.5), 2.0);
        assert_eq!(round_half_up(-0.5), 0.0); // floor(-0.5+0.5) = 0, not -1
        assert_eq!(round_half_up(2.4), 2.0);
    }

    #[test]
    fn weight_codes_cover_range_and_decode_within_bounds() {
        let w: Vec<f32> = (-20..=20).map(|i| i as f32 / 5.0).collect();
        for bits in 1..=5 {
            let q = quantize_weights(&w, bits);
            let max_code = (1u32 << bits) - 1;
            assert!(q.codes.iter().all(|&c| (c as u32) <= max_code));
            // extreme weights map to the extreme codes
            assert_eq!(q.codes[0], 0);
            assert_eq!(q.codes[w.len() - 1] as u32, max_code);
            for &c in &q.codes {
                let v = decode_weight(&q, c);
                assert!((-1.0..=1.0).contains(&v));
            }
        }
    }

    #[test]
    fn binary_weights_are_sign_like() {
        let w = [-0.9f32, -0.1, 0.1, 0.9];
        let fq = fake_quant_weights(&w, 1);
        assert_eq!(fq, vec![-1.0, -1.0, 1.0, 1.0]);
    }

    #[test]
    fn act_codes_clip_and_scale() {
        let x = [-1.0f32, 0.0, 3.0, 6.0, 9.0];
        let mut codes = vec![0u8; x.len()];
        let scale = quantize_acts(&x, 6.0, 2, &mut codes);
        assert_eq!(codes, vec![0, 0, 2, 3, 3]); // 3/6*3 = 1.5 → 2 (half up)
        assert!((scale - 2.0).abs() < 1e-6);
    }

    #[test]
    fn act_codes_degenerate_alpha_is_all_zero_not_nan() {
        let x = [-1.0f32, 0.5, 2.0];
        for alpha in [0.0f32, -0.5] {
            let mut codes = vec![7u8; x.len()];
            let scale = quantize_acts(&x, alpha, 3, &mut codes);
            assert_eq!(codes, vec![0, 0, 0], "alpha={alpha}");
            assert_eq!(scale, 0.0, "alpha={alpha}");
        }
    }
}
