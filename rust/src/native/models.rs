//! Rust-side model registry + manifest synthesis for the native backend.
//!
//! Mirrors `python/compile/model.py::MODELS` so the native CPU backend
//! can run without any exported artifact directory: geometry is looked
//! up by name, the layer table is rebuilt with the exact
//! `model.conv_inventory` logic ([`NetDesc::from_geometry`]), and a
//! full [`Manifest`] — including the FLOPs tables the coordinator and
//! reports read — is synthesized in memory.  The synthesized manifest
//! carries no `graphs` entries (there are no HLO files); the native
//! backend interprets graph names directly.

use anyhow::{bail, Result};
use std::collections::HashMap;

use crate::coordinator::flops::MIXED_DIVISOR;
use crate::models::NetDesc;
use crate::runtime::{LeafSpec, Manifest, StageDesc};

/// The paper's candidate bitwidth set B = {1,…,5} (§5 Implementation).
pub const DEFAULT_BITS: [u32; 5] = [1, 2, 3, 4, 5];

/// PACT clip initialization (paper §B.3).
pub const DEFAULT_ALPHA_INIT: f32 = 6.0;

/// Static description of one model variant (mirror of `model.ModelCfg`).
#[derive(Debug, Clone)]
pub struct NativeModelCfg {
    pub name: &'static str,
    pub image: [usize; 3],
    pub num_classes: usize,
    pub stem_channels: usize,
    pub stages: Vec<StageDesc>,
    pub batch_size: usize,
}

fn stage(channels: usize, blocks: usize, stride: usize) -> StageDesc {
    StageDesc { channels, blocks, stride }
}

fn cifar_resnet(name: &'static str, n: usize, batch: usize) -> NativeModelCfg {
    NativeModelCfg {
        name,
        image: [32, 32, 3],
        num_classes: 10,
        stem_channels: 16,
        stages: vec![stage(16, n, 1), stage(32, n, 2), stage(64, n, 2)],
        batch_size: batch,
    }
}

/// Look up a model variant by name (`model.py` registry parity).
pub fn lookup(name: &str) -> Option<NativeModelCfg> {
    Some(match name {
        "resnet8_tiny" => NativeModelCfg {
            name: "resnet8_tiny",
            image: [16, 16, 3],
            num_classes: 10,
            stem_channels: 8,
            stages: vec![stage(8, 1, 1), stage(16, 1, 2), stage(32, 1, 2)],
            batch_size: 16,
        },
        "resnet20_synth" => cifar_resnet("resnet20_synth", 3, 32),
        "resnet32_synth" => cifar_resnet("resnet32_synth", 5, 32),
        "resnet56_synth" => cifar_resnet("resnet56_synth", 9, 32),
        "resnet18_synth" => NativeModelCfg {
            name: "resnet18_synth",
            image: [32, 32, 3],
            num_classes: 40,
            stem_channels: 32,
            stages: vec![stage(32, 2, 1), stage(64, 2, 2), stage(128, 2, 2), stage(256, 2, 2)],
            batch_size: 16,
        },
        "resnet34_synth" => NativeModelCfg {
            name: "resnet34_synth",
            image: [32, 32, 3],
            num_classes: 40,
            stem_channels: 32,
            stages: vec![stage(32, 3, 1), stage(64, 4, 2), stage(128, 6, 2), stage(256, 3, 2)],
            batch_size: 16,
        },
        _ => return None,
    })
}

/// Every registered variant name; [`lookup`] must resolve each (unit
/// tested below, so the list and the match arms cannot drift apart).
const REGISTRY: [&str; 6] = [
    "resnet8_tiny",
    "resnet20_synth",
    "resnet32_synth",
    "resnet56_synth",
    "resnet18_synth",
    "resnet34_synth",
];

/// Names of all registered variants (for error messages / docs).
pub fn registry_names() -> &'static [&'static str] {
    &REGISTRY
}

/// State-spec construction: the canonical flattened leaf order mirrors
/// `aot.py`'s pytree flattening (sorted dict keys at every level), so a
/// native checkpoint and an artifact checkpoint of the same model list
/// leaves in the same order.
fn state_spec(net: &NetDesc, n_bits: usize) -> Vec<LeafSpec> {
    let f32_leaf = |path: String, shape: Vec<usize>| LeafSpec {
        path,
        shape,
        dtype: crate::runtime::DType::F32,
    };

    let mut qnames: Vec<String> = net.qconv_names.clone();
    qnames.sort();

    // params group keys: every conv/fc + "bn_<conv>" for non-fc layers.
    struct P {
        key: String,
        leaves: Vec<(String, Vec<usize>)>,
    }
    let mut params: Vec<P> = Vec::new();
    for l in net.inventory() {
        if l.kind == "fc" {
            params.push(P {
                key: l.name.clone(),
                leaves: vec![
                    ("b".into(), vec![l.out_ch]),
                    ("w".into(), vec![l.in_ch, l.out_ch]),
                ],
            });
            continue;
        }
        params.push(P {
            key: l.name.clone(),
            leaves: vec![("w".into(), vec![l.ksize, l.ksize, l.in_ch, l.out_ch])],
        });
        params.push(P {
            key: format!("bn_{}", l.name),
            leaves: vec![("beta".into(), vec![l.out_ch]), ("gamma".into(), vec![l.out_ch])],
        });
    }
    params.sort_by(|a, b| a.key.cmp(&b.key));

    let mut bn: Vec<(String, usize)> = net
        .inventory()
        .iter()
        .filter(|l| l.kind != "fc")
        .map(|l| (l.name.clone(), l.out_ch))
        .collect();
    bn.sort();

    let mut spec = Vec::new();
    // 1. alphas (scalar per qconv, sorted by name)
    for n in &qnames {
        spec.push(f32_leaf(format!("state/alphas/{n}"), vec![]));
    }
    // 2. arch: r then s (sorted keys "r" < "s"), each sorted by layer
    for group in ["r", "s"] {
        for n in &qnames {
            spec.push(f32_leaf(format!("state/arch/{group}/{n}"), vec![n_bits]));
        }
    }
    // 3. bn running stats: per conv sorted, leaves mean < var
    for (n, ch) in &bn {
        spec.push(f32_leaf(format!("state/bn/{n}/mean"), vec![*ch]));
        spec.push(f32_leaf(format!("state/bn/{n}/var"), vec![*ch]));
    }
    // 4. opt: adam ("m" < "t" < "v") then mom — "adam" < "mom".
    for group in ["r", "s"] {
        for n in &qnames {
            spec.push(f32_leaf(format!("state/opt/adam/m/{group}/{n}"), vec![n_bits]));
        }
    }
    spec.push(f32_leaf("state/opt/adam/t".into(), vec![]));
    for group in ["r", "s"] {
        for n in &qnames {
            spec.push(f32_leaf(format!("state/opt/adam/v/{group}/{n}"), vec![n_bits]));
        }
    }
    for n in &qnames {
        spec.push(f32_leaf(format!("state/opt/mom/alphas/{n}"), vec![]));
    }
    for p in &params {
        for (leaf, shape) in &p.leaves {
            spec.push(f32_leaf(
                format!("state/opt/mom/params/{}/{leaf}", p.key),
                shape.clone(),
            ));
        }
    }
    // 5. params
    for p in &params {
        for (leaf, shape) in &p.leaves {
            spec.push(f32_leaf(format!("state/params/{}/{leaf}", p.key), shape.clone()));
        }
    }
    spec
}

/// Synthesize a full [`Manifest`] for a registered model.  Semantically
/// identical to loading `manifest.json` produced by `aot.py` for the
/// same variant, minus the `graphs` table (the native backend needs no
/// HLO files) and the python-side RNG (native init uses `util::Rng`).
pub fn synthesize_manifest(cfg: &NativeModelCfg) -> Result<Manifest> {
    let net = NetDesc::from_geometry(cfg.image, cfg.stem_channels, &cfg.stages, cfg.num_classes);
    let layers: Vec<_> = net.inventory().into_iter().cloned().collect();
    let fp_macs: u64 = layers.iter().filter(|l| l.kind != "qconv").map(|l| l.macs).sum();
    let qconv_macs: HashMap<String, u64> = layers
        .iter()
        .filter(|l| l.kind == "qconv")
        .map(|l| (l.name.clone(), l.macs))
        .collect();
    let total_macs: u64 = layers.iter().map(|l| l.macs).sum();
    let qmac_sum: u64 = qconv_macs.values().sum();
    let bits: Vec<u32> = DEFAULT_BITS.to_vec();
    let uniform_mflops: HashMap<u32, f64> = bits
        .iter()
        .map(|&b| {
            let cost = fp_macs as f64 + qmac_sum as f64 * (b * b) as f64 / MIXED_DIVISOR;
            (b, cost / 1e6)
        })
        .collect();
    if net.qconv_names.is_empty() {
        bail!("model {} has no quantized convs", cfg.name);
    }
    let spec = state_spec(&net, bits.len());
    Ok(Manifest {
        model: cfg.name.to_string(),
        dir: std::path::PathBuf::new(),
        batch_size: cfg.batch_size,
        image: cfg.image,
        num_classes: cfg.num_classes,
        bits,
        alpha_init: DEFAULT_ALPHA_INIT,
        stem_channels: cfg.stem_channels,
        stages: cfg.stages.clone(),
        qconv_layers: net.qconv_names.clone(),
        layers,
        fp_macs,
        qconv_macs,
        fp32_mflops: total_macs as f64 / 1e6,
        uniform_mflops,
        state_spec: spec,
        graphs: HashMap::new(),
        dnas_state_spec: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::FlopsModel;

    #[test]
    fn every_registry_name_resolves_and_roundtrips() {
        for name in registry_names() {
            let cfg = lookup(name)
                .unwrap_or_else(|| panic!("registry_names lists '{name}' but lookup misses it"));
            assert_eq!(cfg.name, *name);
        }
    }

    #[test]
    fn synthesized_manifest_passes_topology_parity() {
        for name in registry_names() {
            let cfg = lookup(name).unwrap();
            let m = synthesize_manifest(&cfg).unwrap();
            // NetDesc::from_manifest runs the structural parity check.
            let net = NetDesc::from_manifest(&m).unwrap();
            assert_eq!(net.qconv_names, m.qconv_layers, "{name}");
            assert!(m.fp_macs > 0 && m.fp32_mflops > 0.0, "{name}");
        }
    }

    #[test]
    fn uniform_mflops_table_matches_flops_model() {
        let m = synthesize_manifest(&lookup("resnet8_tiny").unwrap()).unwrap();
        let f = FlopsModel::from_manifest(&m).unwrap();
        for &b in &m.bits {
            let got = m.uniform_mflops[&b];
            let want = f.uniform_mflops(b);
            assert!((got - want).abs() < 1e-9, "bit {b}: {got} vs {want}");
        }
    }

    #[test]
    fn state_spec_is_complete_and_unique() {
        let m = synthesize_manifest(&lookup("resnet8_tiny").unwrap()).unwrap();
        let mut seen = std::collections::HashSet::new();
        for l in &m.state_spec {
            assert!(seen.insert(l.path.clone()), "duplicate leaf {}", l.path);
        }
        // every qconv owns alpha, arch r/s, adam m/v, momentum, weights, bn
        for n in &m.qconv_layers {
            for p in [
                format!("state/alphas/{n}"),
                format!("state/arch/r/{n}"),
                format!("state/arch/s/{n}"),
                format!("state/opt/adam/m/r/{n}"),
                format!("state/opt/adam/v/s/{n}"),
                format!("state/opt/mom/alphas/{n}"),
                format!("state/opt/mom/params/{n}/w"),
                format!("state/params/{n}/w"),
                format!("state/params/bn_{n}/gamma"),
                format!("state/bn/{n}/mean"),
            ] {
                assert!(seen.contains(&p), "missing leaf {p}");
            }
        }
        for p in [
            "state/params/stem/w",
            "state/params/fc/w",
            "state/params/fc/b",
            "state/opt/adam/t",
        ] {
            assert!(seen.contains(p), "missing leaf {p}");
        }
    }
}
