//! Length-prefixed wire protocol of `ebs serve` (DESIGN.md §13).
//!
//! Transport-agnostic: the same frames flow over TCP or stdin/stdout.
//! Every message is `[u32 LE payload_len][payload]`; payloads start
//! with a one-byte opcode and a `u32 LE` client-chosen request id that
//! the matching response echoes (responses to pipelined requests may
//! arrive out of order — different micro-batches complete at different
//! times).
//!
//! Requests:
//! * `0x01` classify — `[op][id][count u32][count·H·W·C f32 LE]`
//! * `0x02` stats    — `[op][id]`
//! * `0x03` shutdown — `[op][id]` (graceful: queued work drains first)
//!
//! Responses:
//! * `0x01` classify — `[op][id][count u32][count u32-labels]`
//! * `0x02` stats    — `[op][id][UTF-8 JSON]` (includes `input_hw` /
//!   `input_ch` / `classes`, so clients can size requests)
//! * `0x03` shutdown ack — `[op][id]`
//! * `0xFF` error    — `[op][id][code u8][UTF-8 message]`

use std::io::{Read, Write};

use anyhow::{bail, Result};

/// Hard cap on a frame payload (a 32×32×3 float image is 12 KiB; this
/// allows ~5k of them per request while bounding a bad header's damage).
pub const MAX_FRAME: usize = 64 << 20;

pub const OP_CLASSIFY: u8 = 0x01;
pub const OP_STATS: u8 = 0x02;
pub const OP_SHUTDOWN: u8 = 0x03;
pub const OP_ERROR: u8 = 0xFF;

/// Error codes carried by `0xFF` responses.
pub const ERR_OVERLOADED: u8 = 1;
pub const ERR_SHUTTING_DOWN: u8 = 2;
pub const ERR_BAD_REQUEST: u8 = 3;

#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Classify { id: u32, count: u32, images: Vec<f32> },
    Stats { id: u32 },
    Shutdown { id: u32 },
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Response {
    Classify { id: u32, labels: Vec<u32> },
    Stats { id: u32, json: String },
    ShutdownAck { id: u32 },
    Error { id: u32, code: u8, msg: String },
}

/// Read one frame's payload; `Ok(None)` on clean EOF at a frame
/// boundary (client hung up between requests).
pub fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => bail!("truncated frame header ({got} of 4 length bytes)"),
            Ok(n) => got += n,
            // retry EINTR like read_exact does — a signal mid-header
            // must not kill a healthy connection
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        bail!("frame of {len} bytes exceeds the {MAX_FRAME}-byte cap");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(Some(payload))
}

/// Write `[len][payload]` (no flush — callers batch and flush).
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> std::io::Result<()> {
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)
}

fn take_u32(b: &[u8], at: usize, what: &str) -> Result<u32> {
    match b.get(at..at + 4) {
        Some(s) => Ok(u32::from_le_bytes(s.try_into().unwrap())),
        None => bail!("frame too short for {what}"),
    }
}

/// Decode a request payload (geometry validation — does `count` match
/// the served model — happens in the session layer, which knows the
/// image size).
pub fn decode_request(payload: &[u8]) -> Result<Request> {
    let Some(&op) = payload.first() else { bail!("empty frame") };
    let id = take_u32(payload, 1, "request id")?;
    match op {
        OP_CLASSIFY => {
            let count = take_u32(payload, 5, "image count")?;
            let body = &payload[9..];
            if body.len() % 4 != 0 {
                bail!("classify body of {} bytes is not f32-aligned", body.len());
            }
            let images: Vec<f32> = body
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(Request::Classify { id, count, images })
        }
        OP_STATS => Ok(Request::Stats { id }),
        OP_SHUTDOWN => Ok(Request::Shutdown { id }),
        other => bail!("unknown request opcode 0x{other:02x}"),
    }
}

/// Encode a full request frame (length prefix included) — the client
/// half, used by tests, the bench, and the CI smoke driver.
pub fn encode_request(req: &Request) -> Vec<u8> {
    let mut p = Vec::new();
    match req {
        Request::Classify { id, count, images } => {
            p.push(OP_CLASSIFY);
            p.extend_from_slice(&id.to_le_bytes());
            p.extend_from_slice(&count.to_le_bytes());
            for v in images {
                p.extend_from_slice(&v.to_le_bytes());
            }
        }
        Request::Stats { id } => {
            p.push(OP_STATS);
            p.extend_from_slice(&id.to_le_bytes());
        }
        Request::Shutdown { id } => {
            p.push(OP_SHUTDOWN);
            p.extend_from_slice(&id.to_le_bytes());
        }
    }
    frame(p)
}

/// Encode a full response frame (length prefix included).
pub fn encode_response(resp: &Response) -> Vec<u8> {
    let mut p = Vec::new();
    match resp {
        Response::Classify { id, labels } => {
            p.push(OP_CLASSIFY);
            p.extend_from_slice(&id.to_le_bytes());
            p.extend_from_slice(&(labels.len() as u32).to_le_bytes());
            for l in labels {
                p.extend_from_slice(&l.to_le_bytes());
            }
        }
        Response::Stats { id, json } => {
            p.push(OP_STATS);
            p.extend_from_slice(&id.to_le_bytes());
            p.extend_from_slice(json.as_bytes());
        }
        Response::ShutdownAck { id } => {
            p.push(OP_SHUTDOWN);
            p.extend_from_slice(&id.to_le_bytes());
        }
        Response::Error { id, code, msg } => {
            p.push(OP_ERROR);
            p.extend_from_slice(&id.to_le_bytes());
            p.push(*code);
            p.extend_from_slice(msg.as_bytes());
        }
    }
    frame(p)
}

/// Decode a response payload — the client half.
pub fn decode_response(payload: &[u8]) -> Result<Response> {
    let Some(&op) = payload.first() else { bail!("empty frame") };
    let id = take_u32(payload, 1, "response id")?;
    match op {
        OP_CLASSIFY => {
            let count = take_u32(payload, 5, "label count")? as usize;
            let body = &payload[9..];
            if body.len() != count * 4 {
                bail!("classify response body {} bytes, want {}", body.len(), count * 4);
            }
            let labels = body
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(Response::Classify { id, labels })
        }
        OP_STATS => Ok(Response::Stats { id, json: String::from_utf8(payload[5..].to_vec())? }),
        OP_SHUTDOWN => Ok(Response::ShutdownAck { id }),
        OP_ERROR => {
            let Some(&code) = payload.get(5) else { bail!("error frame missing code") };
            Ok(Response::Error {
                id,
                code,
                msg: String::from_utf8_lossy(&payload[6..]).into_owned(),
            })
        }
        other => bail!("unknown response opcode 0x{other:02x}"),
    }
}

fn frame(payload: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_request(req: &Request) -> Request {
        let frame = encode_request(req);
        let mut cursor = &frame[..];
        let payload = read_frame(&mut cursor).unwrap().unwrap();
        assert!(cursor.is_empty(), "frame length prefix must cover the payload exactly");
        decode_request(&payload).unwrap()
    }

    fn roundtrip_response(resp: &Response) -> Response {
        let frame = encode_response(resp);
        let mut cursor = &frame[..];
        let payload = read_frame(&mut cursor).unwrap().unwrap();
        decode_response(&payload).unwrap()
    }

    #[test]
    fn request_roundtrips() {
        for req in [
            Request::Classify { id: 7, count: 2, images: vec![0.5, -1.25, 3.0, f32::MIN_POSITIVE] },
            Request::Stats { id: 0xFFFF_FFFF },
            Request::Shutdown { id: 0 },
        ] {
            assert_eq!(roundtrip_request(&req), req);
        }
    }

    #[test]
    fn response_roundtrips() {
        for resp in [
            Response::Classify { id: 9, labels: vec![3, 0, 7] },
            Response::Stats { id: 1, json: "{\"images\": 4}".into() },
            Response::ShutdownAck { id: 2 },
            Response::Error { id: 3, code: ERR_OVERLOADED, msg: "queue full".into() },
        ] {
            assert_eq!(roundtrip_response(&resp), resp);
        }
    }

    #[test]
    fn clean_eof_and_truncation_are_distinguished() {
        let mut empty: &[u8] = &[];
        assert!(read_frame(&mut empty).unwrap().is_none(), "EOF at a boundary is clean");
        let mut torn: &[u8] = &[5, 0];
        assert!(read_frame(&mut torn).is_err(), "torn header is an error");
        let mut short: &[u8] = &[8, 0, 0, 0, 1, 2];
        assert!(read_frame(&mut short).is_err(), "payload shorter than the prefix is an error");
    }

    #[test]
    fn oversized_frames_are_rejected_before_allocation() {
        let huge = (MAX_FRAME as u32 + 1).to_le_bytes();
        let mut r: &[u8] = &huge;
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn garbage_payloads_fail_to_decode() {
        assert!(decode_request(&[]).is_err());
        assert!(decode_request(&[0x42, 0, 0, 0, 0]).is_err(), "unknown opcode");
        assert!(decode_request(&[OP_CLASSIFY, 1, 0, 0, 0, 2, 0, 0, 0, 9]).is_err(), "unaligned body");
        assert!(decode_response(&[OP_ERROR, 1, 0, 0, 0]).is_err(), "error frame missing code");
    }
}
