//! Table 4 regenerator: Binary Decomposition latency per conv layer,
//! W1-A1 vs W1-A2 (plus optional wider sweeps), a Bi-Real-18-style
//! end-to-end stack, and the serial vs tiled vs parallel engine sweep
//! at batch 1/8/32 (Table 4c — the practical-deployment claim at scale).
//!
//! The paper measures a Raspberry Pi 3B (ARM NEON, daBNN); we measure
//! the same layer shapes on the x86-64 AND+POPCNT engine — the claim
//! being reproduced is the *ratio* structure: latency scales ~linearly
//! with M·K, so W1-A2 ≈ 2× W1-A1 (Eq. 2 operation count).
//!
//! `run_full` additionally emits a machine-readable JSON document
//! (schema in DESIGN.md §9) consumed by CI as the perf trajectory
//! artifact (`BENCH_bd_layers.json`).

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::kernels::auto_threads;
use crate::bd::{BdConvLayer, BdEngineCfg, BdExec, BdScratch};
use crate::util::json::Json;
use crate::util::Rng;

use super::table_fmt::Table;

/// One benchmark shape (from the paper's Table 4: ResNet-18 layers).
#[derive(Debug, Clone, Copy)]
pub struct LayerShape {
    pub k: usize,
    pub ci: usize,
    pub co: usize,
    pub stride: usize,
    pub hw: usize,
}

/// The paper's Table 4 layer list; feature-map sizes follow the
/// ResNet-18 positions of those channel counts (56/28/14/14/7 at 224²
/// input, scaled 4× down here to keep single-core runtimes sane — the
/// M·K ratio is size-independent).
pub fn paper_layers() -> Vec<LayerShape> {
    vec![
        LayerShape { k: 3, ci: 64, co: 64, stride: 1, hw: 14 },
        LayerShape { k: 3, ci: 128, co: 128, stride: 1, hw: 7 },
        LayerShape { k: 3, ci: 256, co: 256, stride: 1, hw: 4 },
        LayerShape { k: 3, ci: 256, co: 512, stride: 2, hw: 4 },
        LayerShape { k: 3, ci: 512, co: 512, stride: 1, hw: 2 },
    ]
}

fn build_layer(shape: &LayerShape, m_bits: u32, k_bits: u32, cfg: BdEngineCfg) -> BdConvLayer {
    let mut rng = Rng::new(0x7AB4 ^ ((m_bits as u64) << 8) ^ k_bits as u64);
    let wlen = shape.k * shape.k * shape.ci * shape.co;
    let weights: Vec<f32> = (0..wlen).map(|_| rng.normal()).collect();
    let mut layer = BdConvLayer::new(
        "bench", &weights, shape.ci, shape.co, shape.k, shape.stride,
        m_bits, k_bits, 4.0, None, true,
    )
    .expect("layer");
    layer.engine = cfg;
    layer
}

/// Median-of-`reps` latency of one BD layer at (m_bits, k_bits) on the
/// serial engine, batch 1 (the original Table 4 measurement).
pub fn layer_latency_ms(shape: &LayerShape, m_bits: u32, k_bits: u32, reps: usize) -> f64 {
    layer_latency_ms_cfg(shape, m_bits, k_bits, reps, 1, BdEngineCfg::serial())
}

/// Median-of-`reps` latency of one *batched* BD layer forward under an
/// explicit engine configuration.  Scratch buffers are reused across
/// reps, so this measures the allocation-free steady state.
pub fn layer_latency_ms_cfg(
    shape: &LayerShape,
    m_bits: u32,
    k_bits: u32,
    reps: usize,
    batch: usize,
    cfg: BdEngineCfg,
) -> f64 {
    let layer = build_layer(shape, m_bits, k_bits, cfg);
    let mut rng = Rng::new(0xDA7A ^ batch as u64);
    let x: Vec<f32> =
        (0..batch * shape.hw * shape.hw * shape.ci).map(|_| rng.normal().abs()).collect();
    let mut scratch = BdScratch::new();
    let mut out = Vec::new();
    layer.forward_batch_into(&x, batch, shape.hw, shape.hw, &mut scratch, &mut out); // warmup
    let mut times: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t0 = Instant::now();
            layer.forward_batch_into(&x, batch, shape.hw, shape.hw, &mut scratch, &mut out);
            std::hint::black_box(&out);
            t0.elapsed().as_secs_f64() * 1e3
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// Regenerate Table 4 (original serial measurements only).
pub fn run(out: &Path, reps: usize, extended: bool) -> Result<()> {
    run_full(out, reps, extended, None)
}

/// Table 4 skeleton — shared by [`run_full`] and the golden formatting
/// tests in `tests/golden_reports.rs`.
pub fn skeleton() -> Table {
    Table::new(
        "Table 4 — BD latency per layer (x86-64 AND+POPCNT engine)",
        &[
            "Kernel", "In ch", "Out ch", "Stride", "W1-A1 (ms)", "W1-A2 (ms)",
            "ratio", "W2-A2 (ms)",
        ],
    )
}

/// Table 4c (batched engine sweep) skeleton.
pub fn sweep_skeleton(threads: usize) -> Table {
    Table::new(
        &format!("Table 4c — batched engine, serial vs tiled vs parallel ({threads} threads)"),
        &[
            "Shape", "M,K", "Batch", "serial ms/img", "tiled ms/img", "par ms/img",
            "par speedup",
        ],
    )
}

/// Regenerate Table 4 plus the engine sweep (Table 4c); optionally emit
/// the machine-readable JSON at `json_path`.
pub fn run_full(out: &Path, reps: usize, extended: bool, json_path: Option<&Path>) -> Result<()> {
    let mut table = skeleton();
    for shape in paper_layers() {
        let a = layer_latency_ms(&shape, 1, 1, reps);
        let b = layer_latency_ms(&shape, 1, 2, reps);
        let c = layer_latency_ms(&shape, 2, 2, reps);
        table.row(vec![
            shape.k.to_string(),
            shape.ci.to_string(),
            shape.co.to_string(),
            shape.stride.to_string(),
            format!("{a:.2}"),
            format!("{b:.2}"),
            format!("{:.2}x", b / a),
            format!("{c:.2}"),
        ]);
    }

    // Bi-Real-18-like stack: the quantized body of ResNet-18 (4 stages ×
    // 2 blocks × 2 convs) at W1-A1 vs W1-A2 — the paper's last row.
    let stack: Vec<LayerShape> = {
        let mut v = Vec::new();
        let stages = [(64usize, 14usize), (128, 7), (256, 4), (512, 2)];
        for &(ch, hw) in &stages {
            for _ in 0..4 {
                v.push(LayerShape { k: 3, ci: ch, co: ch, stride: 1, hw });
            }
        }
        v
    };
    let sum = |m: u32, k: u32| -> f64 {
        stack.iter().map(|s| layer_latency_ms(s, m, k, reps.max(2) / 2)).sum()
    };
    let s11 = sum(1, 1);
    let s12 = sum(1, 2);
    table.row(vec![
        "Bi-Real-18 body".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{s11:.1}"),
        format!("{s12:.1}"),
        format!("{:.2}x", s12 / s11),
        "-".into(),
    ]);
    table.write(out, "table4")?;

    // Table 4c: serial vs tiled vs parallel at batch 1/8/32 — the
    // batched serving claim.  Per-image latencies so rows are comparable.
    let threads = auto_threads();
    let mut sweep = sweep_skeleton(threads);
    let mut json_rows = Vec::new();
    let sweep_shapes =
        [LayerShape { k: 3, ci: 64, co: 64, stride: 1, hw: 14 }, LayerShape {
            k: 3,
            ci: 128,
            co: 128,
            stride: 1,
            hw: 7,
        }];
    let (mb, kb) = (2u32, 2u32);
    for shape in sweep_shapes {
        for batch in [1usize, 8, 32] {
            let cfg = |exec: BdExec| BdEngineCfg { exec, ..BdEngineCfg::default() };
            let serial = layer_latency_ms_cfg(&shape, mb, kb, reps, batch, cfg(BdExec::Serial));
            let tiled = layer_latency_ms_cfg(&shape, mb, kb, reps, batch, cfg(BdExec::Tiled));
            let par = layer_latency_ms_cfg(&shape, mb, kb, reps, batch, cfg(BdExec::Parallel));
            let bf = batch as f64;
            sweep.row(vec![
                format!("{}x{} {}→{} @{}²", shape.k, shape.k, shape.ci, shape.co, shape.hw),
                format!("{mb},{kb}"),
                batch.to_string(),
                format!("{:.3}", serial / bf),
                format!("{:.3}", tiled / bf),
                format!("{:.3}", par / bf),
                format!("{:.2}x", serial / par),
            ]);
            json_rows.push(Json::Obj(vec![
                ("k".into(), Json::Num(shape.k as f64)),
                ("ci".into(), Json::Num(shape.ci as f64)),
                ("co".into(), Json::Num(shape.co as f64)),
                ("stride".into(), Json::Num(shape.stride as f64)),
                ("hw".into(), Json::Num(shape.hw as f64)),
                ("m_bits".into(), Json::Num(mb as f64)),
                ("k_bits".into(), Json::Num(kb as f64)),
                ("batch".into(), Json::Num(batch as f64)),
                ("serial_ms".into(), Json::Num(serial)),
                ("tiled_ms".into(), Json::Num(tiled)),
                ("par_ms".into(), Json::Num(par)),
                ("par_speedup".into(), Json::Num(serial / par)),
            ]));
        }
    }
    sweep.write(out, "table4c")?;

    if let Some(path) = json_path {
        let tiles = BdEngineCfg::default().tiles;
        crate::util::json::write_bench_json(
            path,
            "bd_layers",
            reps,
            threads,
            (tiles.co_tile, tiles.n_tile),
            json_rows,
        )?;
        println!("[report] wrote {}", path.display());
    }

    if extended {
        // Full M×K sweep on one representative layer: latency should be
        // ~linear in M·K (Eq. 2).
        let shape = LayerShape { k: 3, ci: 128, co: 128, stride: 1, hw: 7 };
        let mut sweep = Table::new(
            "Table 4b — latency vs M·K (128ch 3×3, Eq. 2 linearity)",
            &["M", "K", "M*K", "ms", "ms/(M*K)"],
        );
        for m in 1..=5u32 {
            for k in 1..=5u32 {
                let ms = layer_latency_ms(&shape, m, k, reps);
                sweep.row(vec![
                    m.to_string(),
                    k.to_string(),
                    (m * k).to_string(),
                    format!("{ms:.2}"),
                    format!("{:.3}", ms / (m * k) as f64),
                ]);
            }
        }
        sweep.write(out, "table4_sweep")?;
    }
    Ok(())
}
