"""AOT exporter contract tests: manifest invariants and HLO-text
round-trip (the text must parse back into an XlaComputation — the same
path the Rust runtime's `HloModuleProto::from_text_file` exercises)."""

import json
import os

import jax
import jax.numpy as jnp
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, steps
from compile.model import MODELS, init_state, qconv_names


@pytest.fixture(scope="module")
def tiny_export(tmp_path_factory):
    out = tmp_path_factory.mktemp("art")
    cfg = MODELS["resnet8_tiny"]
    aot.export_model(cfg, str(out), ["init", "eval", "search_det"], with_dnas=False)
    mdir = os.path.join(str(out), cfg.name)
    with open(os.path.join(mdir, "manifest.json")) as f:
        return cfg, mdir, json.load(f)


def test_manifest_roles_partition_inputs(tiny_export):
    _, _, m = tiny_export
    for gname, g in m["graphs"].items():
        for leaf in g["inputs"]:
            assert leaf["path"].startswith(("state/", "in/")), (gname, leaf["path"])
        for leaf in g["outputs"]:
            assert leaf["path"].startswith(("state/", "out/")), (gname, leaf["path"])


def test_manifest_state_paths_consistent_across_graphs(tiny_export):
    """Every graph's state inputs must be exactly the canonical spec, in
    canonical order — the Rust runtime wiring assumption."""
    _, _, m = tiny_export
    canonical = [l["path"] for l in m["state_spec"]]
    for gname in ("eval", "search_det"):
        g = m["graphs"][gname]
        got = [l["path"] for l in g["inputs"] if l["path"].startswith("state/")]
        assert got == canonical, gname
    # search_det returns the full state
    out_state = [l["path"] for l in m["graphs"]["search_det"]["outputs"] if l["path"].startswith("state/")]
    assert out_state == canonical


def test_manifest_macs_match_inventory(tiny_export):
    cfg, _, m = tiny_export
    from compile.flops import qconv_macs

    assert m["qconv_layers"] == qconv_names(cfg)
    for name, macs in qconv_macs(cfg).items():
        assert m["qconv_macs"][name] == macs


def test_hlo_text_parses_back_to_xla_computation(tiny_export):
    """The exact acceptance criterion of the interchange format."""
    _, mdir, m = tiny_export
    for gname, g in m["graphs"].items():
        with open(os.path.join(mdir, g["file"])) as f:
            text = f.read()
        assert text.startswith("HloModule"), gname
        # xla_client exposes the same HLO-text parser XLA uses.
        comp = xc._xla.hlo_module_from_text(text)
        assert comp is not None, gname


def test_init_graph_is_deterministic_in_seed():
    cfg = MODELS["resnet8_tiny"]
    s1 = init_state(cfg, jnp.int32(9))
    s2 = init_state(cfg, jnp.int32(9))
    l1 = jax.tree_util.tree_leaves(s1)
    l2 = jax.tree_util.tree_leaves(s2)
    for a, b in zip(l1, l2):
        assert (a == b).all()


def test_export_graph_output_arity_matches_manifest(tiny_export):
    cfg, _, m = tiny_export
    g = m["graphs"]["search_det"]
    # run the step in python and compare leaf counts
    state = init_state(cfg, jnp.int32(0))
    step = steps.make_search_det(cfg)
    x = jnp.zeros((cfg.batch_size, *cfg.image), jnp.float32)
    y = jnp.zeros((cfg.batch_size,), jnp.int32)
    s = jnp.float32(0.01)
    out = step(state, {
        "xt": x, "yt": y, "xv": x, "yv": y,
        "lr_w": s, "lr_arch": s, "wd": s, "lam": s, "target": jnp.float32(1.0),
    })
    leaves = jax.tree_util.tree_leaves(out)
    assert len(leaves) == len(g["outputs"])
