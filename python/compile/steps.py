"""L2 step graphs — the units the Rust coordinator executes (Alg. 1).

Every public builder returns a pure function over pytrees.  ``aot.py``
flattens these (state first, then named inputs, then scalars), lowers
them to HLO text and records the leaf ordering in the manifest; the Rust
runtime replays them as `state × batch × scalars → state' × metrics`.

Graph family per model (DESIGN.md §7):

  init            seed → fresh training state
  fp_train        full-precision pre-training step (§B.2 initialization)
  fp_eval         full-precision eval (loss + correct count)
  fp_infer        full-precision logits (label-refinery teacher)
  train           retrain step; one-hot selection vectors are INPUTS
  eval            eval under given selection (loss + correct count)
  infer           logits under given selection (BD parity oracle)
  search_det      Alg. 1 body, deterministic (softmax coefficients)
  search_sto      Alg. 1 body, stochastic (Gumbel-softmax, Eq. 8)

The bilevel structure (Eq. 9-10): the weight phase updates (params, α)
by SGD-momentum on the train batch; the architecture phase updates
(r, s) by Adam on the validation batch with the expected-FLOPs penalty.
Validation forwards use batch statistics but do NOT update the BN
running stats (standard DARTS practice — the weights own the BN state).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import flops, layers, optim
from .kernels import ref
from .model import ModelCfg, decay_mask, forward, init_state, qconv_names


def coeff_dicts(cfg: ModelCfg, sel_w: jnp.ndarray, sel_x: jnp.ndarray):
    """Split (L, N) coefficient matrices into per-layer dicts (manifest order)."""
    names = qconv_names(cfg)
    cw = {name: sel_w[i] for i, name in enumerate(names)}
    cx = {name: sel_x[i] for i, name in enumerate(names)}
    return cw, cx


def _softmax_coeffs(cfg: ModelCfg, arch):
    cw = {n: jax.nn.softmax(arch["r"][n]) for n in qconv_names(cfg)}
    cx = {n: jax.nn.softmax(arch["s"][n]) for n in qconv_names(cfg)}
    return cw, cx


def _gumbel_coeffs(cfg: ModelCfg, arch, g_r, g_s, tau):
    names = qconv_names(cfg)
    cw = {n: ref.gumbel_softmax(arch["r"][n], g_r[i], tau) for i, n in enumerate(names)}
    cx = {n: ref.gumbel_softmax(arch["s"][n], g_s[i], tau) for i, n in enumerate(names)}
    return cw, cx


def _ce_metrics(logits, y):
    return layers.cross_entropy(logits, y), layers.accuracy_count(logits, y)


# ---------------------------------------------------------------------------
# Plain steps
# ---------------------------------------------------------------------------


def make_init(cfg: ModelCfg):
    def init(inputs):
        return {"state": init_state(cfg, inputs["seed"])}

    return init


def _weight_phase(cfg, state, cw, cx, x, y, lr, wd, mu, teacher, quantized):
    """SGD-momentum update of (params, α) on one batch; returns new state."""

    def loss_fn(wa):
        params, alphas = wa
        logits, new_bn = forward(
            cfg, params, alphas, cw, cx, state["bn"], x, train=True, quantized=quantized
        )
        ce = layers.cross_entropy(logits, y)
        loss = ce
        if teacher is not None:
            loss = (1.0 - mu) * ce + mu * layers.distill_loss(logits, teacher)
        return loss, (new_bn, logits, ce)

    (loss, (new_bn, logits, _)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        (state["params"], state["alphas"])
    )
    gp, ga = grads
    masks = decay_mask(cfg, state["params"])
    new_params, new_vp = optim.sgd_momentum(
        state["params"], gp, state["opt"]["mom"]["params"], lr, wd, masks
    )
    new_alphas, new_va = optim.sgd_momentum(
        state["alphas"], ga, state["opt"]["mom"]["alphas"], lr, wd
    )
    new_state = dict(state)
    new_state["params"] = new_params
    new_state["alphas"] = new_alphas
    new_state["bn"] = new_bn
    new_state["opt"] = dict(state["opt"])
    new_state["opt"]["mom"] = {"params": new_vp, "alphas": new_va}
    acc = layers.accuracy_count(logits, y) / y.shape[0]
    return new_state, loss, acc


def make_fp_train(cfg: ModelCfg):
    """Full-precision training step (pretrain stage + Table 1 FP row)."""

    def step(state, inputs):
        ns, loss, acc = _weight_phase(
            cfg, state, None, None, inputs["x"], inputs["y"],
            inputs["lr"], inputs["wd"], None, None, quantized=False,
        )
        return {"state": ns, "out": {"acc": acc, "loss": loss}}

    return step


def make_train(cfg: ModelCfg):
    """Retrain step: selection coefficients (usually one-hot) are inputs.

    ``mu`` blends in the label-refinery KL term; feed mu=0 and zero
    teacher logits to train on hard labels only.
    """

    def step(state, inputs):
        cw, cx = coeff_dicts(cfg, inputs["sel_w"], inputs["sel_x"])
        ns, loss, acc = _weight_phase(
            cfg, state, cw, cx, inputs["x"], inputs["y"],
            inputs["lr"], inputs["wd"], inputs["mu"], inputs["teacher"], quantized=True,
        )
        return {"state": ns, "out": {"acc": acc, "loss": loss}}

    return step


def make_eval(cfg: ModelCfg, quantized: bool):
    """Eval on one batch with running BN stats: (loss, correct count)."""

    def step(state, inputs):
        if quantized:
            cw, cx = coeff_dicts(cfg, inputs["sel_w"], inputs["sel_x"])
        else:
            cw, cx = None, None
        logits, _ = forward(
            cfg, state["params"], state["alphas"], cw, cx, state["bn"],
            inputs["x"], train=False, quantized=quantized,
        )
        loss, correct = _ce_metrics(logits, inputs["y"])
        return {"out": {"correct": correct, "loss": loss}}

    return step


def make_infer(cfg: ModelCfg, quantized: bool):
    """Logits on one batch (BD parity oracle / distillation teacher)."""

    def step(state, inputs):
        if quantized:
            cw, cx = coeff_dicts(cfg, inputs["sel_w"], inputs["sel_x"])
        else:
            cw, cx = None, None
        logits, _ = forward(
            cfg, state["params"], state["alphas"], cw, cx, state["bn"],
            inputs["x"], train=False, quantized=quantized,
        )
        return {"out": {"logits": logits}}

    return step


# ---------------------------------------------------------------------------
# Bilevel search steps (Alg. 1)
# ---------------------------------------------------------------------------


def _arch_phase(cfg, state, coeff_fn, xv, yv, lr_arch, lam, target):
    """Adam update of (r, s) on the validation batch under Eq. 9."""

    def loss_fn(arch):
        cw, cx = coeff_fn(arch)
        logits, _ = forward(
            cfg, state["params"], state["alphas"], cw, cx, state["bn"],
            xv, train=True, quantized=True,
        )
        ce = layers.cross_entropy(logits, yv)
        eflops = flops.expected_mflops(cfg, cw, cx)
        # Relative-overshoot hinge keeps λ comparable across model sizes.
        penalty = lam * jax.nn.relu(eflops - target) / target
        return ce + penalty, (ce, layers.accuracy_count(logits, yv), eflops)

    (_, (val_ce, correct, eflops)), g_arch = jax.value_and_grad(loss_fn, has_aux=True)(
        state["arch"]
    )
    adam_state = state["opt"]["adam"]
    new_arch, new_m, new_v, new_t = optim.adam(
        state["arch"], g_arch, adam_state["m"], adam_state["v"], adam_state["t"], lr_arch
    )
    new_state = dict(state)
    new_state["arch"] = new_arch
    new_state["opt"] = dict(state["opt"])
    new_state["opt"]["adam"] = {"m": new_m, "v": new_v, "t": new_t}
    return new_state, val_ce, correct, eflops


def make_search_det(cfg: ModelCfg):
    """Deterministic EBS search step: softmax(r), softmax(s) coefficients."""

    def step(state, inputs):
        cw, cx = _softmax_coeffs(cfg, state["arch"])
        st1, train_loss, _ = _weight_phase(
            cfg, state, cw, cx, inputs["xt"], inputs["yt"],
            inputs["lr_w"], inputs["wd"], None, None, quantized=True,
        )
        st2, val_loss, correct, eflops = _arch_phase(
            cfg, st1, lambda arch: _softmax_coeffs(cfg, arch),
            inputs["xv"], inputs["yv"], inputs["lr_arch"], inputs["lam"], inputs["target"],
        )
        return {
            "state": st2,
            "out": {
                "eflops": eflops,
                "train_loss": train_loss,
                "val_acc": correct / inputs["yv"].shape[0],
                "val_loss": val_loss,
            },
        }

    return step


def make_search_sto(cfg: ModelCfg):
    """Stochastic EBS search step: Gumbel-softmax coefficients (Eq. 8).

    One Gumbel sample per step (supplied by Rust) is shared by the weight
    and architecture phases.
    """

    def step(state, inputs):
        g_r, g_s, tau = inputs["g_r"], inputs["g_s"], inputs["tau"]
        cw, cx = _gumbel_coeffs(cfg, state["arch"], g_r, g_s, tau)
        st1, train_loss, _ = _weight_phase(
            cfg, state, cw, cx, inputs["xt"], inputs["yt"],
            inputs["lr_w"], inputs["wd"], None, None, quantized=True,
        )
        st2, val_loss, correct, eflops = _arch_phase(
            cfg, st1, lambda arch: _gumbel_coeffs(cfg, arch, g_r, g_s, tau),
            inputs["xv"], inputs["yv"], inputs["lr_arch"], inputs["lam"], inputs["target"],
        )
        return {
            "state": st2,
            "out": {
                "eflops": eflops,
                "train_loss": train_loss,
                "val_acc": correct / inputs["yv"].shape[0],
                "val_loss": val_loss,
            },
        }

    return step
