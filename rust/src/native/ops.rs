//! Dense f32 forward/backward primitives for the native backend.
//!
//! Layout conventions match the HLO graphs and the BD engine: NHWC
//! activations, HWIO weights (flattened `s × co`, `s = k·k·ci` in
//! (kh, kw, ci) order), XLA SAME padding via [`same_pad`].  Backward
//! passes are the exact transposes the autodiff of `steps.py` produces:
//! convolution (dX via col2im of dY·Wᵀ, dW via P·dY), train-mode batch
//! norm with gradients *through* the batch statistics, global average
//! pooling, the linear classifier, and softmax cross-entropy (+ the
//! label-refinery KL term of §B.2).
//!
//! The conv and BN kernels take a `threads` argument and shard across
//! the shared [`crate::kernels`] row partitioner.  Partitioning is
//! always over *disjoint output slices* (conv columns, dW rows, dX
//! images, BN channels/rows) and each element's reduction runs in the
//! same serial order at any worker count, so every kernel is
//! bit-identical at `threads = 1` and `threads = N` (DESIGN.md §12) —
//! the property the same-seed search-replay guarantee stands on.
//! GAP/classifier/softmax stay serial: they are single-pass O(B·co)
//! tails that never show up in the step profile.

use crate::bd::im2col::{im2col_batch_into, same_pad, Patches};
use crate::kernels::{gate_threads, par_row_chunks, par_row_chunks_zip};

/// Columns per cache tile of the threaded conv forward: a tile of
/// `CONV_N_TILE × co` outputs stays L1/L2-resident while the `s`
/// patch rows stream through.
const CONV_N_TILE: usize = 64;

/// out[n][co] = Σ_s patches[s][n] · w[s][co] (the conv-as-GEMM forward),
/// sharded over column ranges of the output; the accumulation over `s`
/// is ascending per output element regardless of tiling or threads.
pub fn conv_forward(p: &Patches, w: &[f32], co: usize, threads: usize, out: &mut Vec<f32>) {
    assert_eq!(w.len(), p.s * co);
    out.clear();
    out.resize(p.n * co, 0.0);
    let (s, n) = (p.s, p.n);
    let threads = gate_threads(threads, (s * n * co) as u64);
    par_row_chunks(out, n, co, threads, |j0, chunk| {
        let jn = chunk.len() / co;
        let mut t0 = 0;
        while t0 < jn {
            let t1 = (t0 + CONV_N_TILE).min(jn);
            let tile = &mut chunk[t0 * co..t1 * co];
            for s_idx in 0..s {
                let wrow = &w[s_idx * co..(s_idx + 1) * co];
                let prow = &p.data[s_idx * n + j0 + t0..s_idx * n + j0 + t1];
                for (&pv, orow) in prow.iter().zip(tile.chunks_exact_mut(co)) {
                    if pv == 0.0 {
                        continue;
                    }
                    for (o, &wv) in orow.iter_mut().zip(wrow) {
                        *o += pv * wv;
                    }
                }
            }
            t0 = t1;
        }
    });
}

/// dW[s][co] = Σ_j patches[s][j] · dY[j][co], accumulated into `dw`
/// (callers zero it), sharded over rows of dW.
pub fn conv_backward_w(p: &Patches, dy: &[f32], co: usize, threads: usize, dw: &mut [f32]) {
    conv_backward_w_cols(p, dy, co, 0, p.n, threads, dw)
}

/// [`conv_backward_w`] restricted to output columns `[j0, j1)` — the
/// per-chunk partial of the dW reduction (DESIGN.md §14): the column
/// range is a canonical-chunk row range of the batch, the accumulation
/// over `j` runs ascending within it, and partials combine in chunk
/// order outside.  The full range reproduces the whole-batch kernel
/// bit-for-bit.
pub fn conv_backward_w_cols(
    p: &Patches,
    dy: &[f32],
    co: usize,
    j0: usize,
    j1: usize,
    threads: usize,
    dw: &mut [f32],
) {
    assert_eq!(dy.len(), p.n * co);
    assert_eq!(dw.len(), p.s * co);
    assert!(j0 <= j1 && j1 <= p.n);
    let (s, n) = (p.s, p.n);
    let threads = gate_threads(threads, (s * (j1 - j0) * co) as u64);
    par_row_chunks(dw, s, co, threads, |s0, chunk| {
        for (si, drow) in chunk.chunks_exact_mut(co).enumerate() {
            let prow = &p.data[(s0 + si) * n + j0..(s0 + si) * n + j1];
            for (jj, &pv) in prow.iter().enumerate() {
                if pv == 0.0 {
                    continue;
                }
                let j = j0 + jj;
                let dyrow = &dy[j * co..(j + 1) * co];
                for (d, &g) in drow.iter_mut().zip(dyrow) {
                    *d += pv * g;
                }
            }
        }
    });
}

/// dX from dY: dPatch[s][j] = Σ_co w[s][co]·dY[j][co], scattered back
/// through the im2col geometry (the exact adjoint of
/// [`im2col_batch_into`]'s gather, including SAME padding drops).
/// Sharded over images — each worker owns the disjoint dX slice of its
/// batch range, so the overlapping-window scatter never races.
#[allow(clippy::too_many_arguments)]
pub fn conv_backward_x(
    dy: &[f32],
    w: &[f32],
    batch: usize,
    h: usize,
    wd: usize,
    ci: usize,
    co: usize,
    k: usize,
    stride: usize,
    threads: usize,
    dx: &mut [f32],
) {
    let (oh, pad_top, _) = same_pad(h, k, stride);
    let (ow, pad_left, _) = same_pad(wd, k, stride);
    let n1 = oh * ow;
    assert_eq!(dy.len(), batch * n1 * co);
    assert_eq!(dx.len(), batch * h * wd * ci);
    let img_sz = h * wd * ci;
    let threads = gate_threads(threads, (batch * n1 * k * k * ci * co) as u64);
    par_row_chunks(dx, batch, img_sz, threads, |b0, chunk| {
        for (bi, dxi) in chunk.chunks_exact_mut(img_sz).enumerate() {
            let b = b0 + bi;
            dxi.fill(0.0);
            for oy in 0..oh {
                for ox in 0..ow {
                    let col = b * n1 + oy * ow + ox;
                    let dyrow = &dy[col * co..(col + 1) * co];
                    for kh in 0..k {
                        let iy = (oy * stride + kh) as isize - pad_top as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kw in 0..k {
                            let ix = (ox * stride + kw) as isize - pad_left as isize;
                            if ix < 0 || ix >= wd as isize {
                                continue;
                            }
                            let dst = ((iy as usize) * wd + ix as usize) * ci;
                            let wrow_base = (kh * k + kw) * ci;
                            for c in 0..ci {
                                let wrow = &w[(wrow_base + c) * co..(wrow_base + c + 1) * co];
                                let mut acc = 0f32;
                                for (&wv, &g) in wrow.iter().zip(dyrow) {
                                    acc += wv * g;
                                }
                                dxi[dst + c] += acc;
                            }
                        }
                    }
                }
            }
        }
    });
}

/// Gather im2col patches (shared scratch-friendly wrapper); returns
/// `true` when the patch buffer had to grow (arena accounting).
#[allow(clippy::too_many_arguments)]
pub fn patches_of(
    x: &[f32],
    batch: usize,
    h: usize,
    w: usize,
    ci: usize,
    k: usize,
    stride: usize,
    p: &mut Patches,
) -> bool {
    im2col_batch_into(x, batch, h, w, ci, k, stride, p)
}

pub const BN_MOMENTUM: f32 = 0.9;
pub const BN_EPS: f32 = 1e-5;

/// Train-mode batch-norm tape: normalized values + per-channel inv-std.
#[derive(Debug, Clone, Default)]
pub struct BnTape {
    pub xhat: Vec<f32>,
    pub inv_std: Vec<f32>,
}

/// Reusable f64 per-channel accumulators for the BN kernels (mean/var
/// on the forward, Σdy/Σdy·x̂ on the backward) — arena-owned so the
/// train step allocates nothing in steady state.
#[derive(Debug, Clone, Default)]
pub struct BnScratch {
    a: Vec<f64>,
    b: Vec<f64>,
}

/// Train-mode BN over an NHWC buffer laid out `n × co` (n = B·H·W).
/// Returns y; fills the tape and the new running stats (momentum 0.9,
/// biased batch variance, matching `layers.batch_norm`).  The
/// per-channel statistics shard over channel ranges — each channel's
/// f64 sum runs rows-ascending on one worker, identical to the serial
/// order — and the normalize pass shards over rows.
#[allow(clippy::too_many_arguments)]
pub fn bn_forward_train(
    x: &[f32],
    co: usize,
    gamma: &[f32],
    beta: &[f32],
    run_mean: &[f32],
    run_var: &[f32],
    threads: usize,
    y: &mut Vec<f32>,
    tape: &mut BnTape,
    new_mean: &mut Vec<f32>,
    new_var: &mut Vec<f32>,
    scratch: &mut BnScratch,
) {
    let n = x.len() / co;
    assert_eq!(x.len(), n * co);
    let stat_threads = gate_threads(threads, 2 * x.len() as u64).min(co);
    let BnScratch { a: mean, b: var } = scratch;
    mean.clear();
    mean.resize(co, 0.0);
    par_row_chunks(mean, co, 1, stat_threads, |c0, mchunk| {
        for row in x.chunks_exact(co) {
            for (m, &v) in mchunk.iter_mut().zip(&row[c0..c0 + mchunk.len()]) {
                *m += v as f64;
            }
        }
    });
    for m in mean.iter_mut() {
        *m /= n as f64;
    }
    var.clear();
    var.resize(co, 0.0);
    {
        let mean = &*mean;
        par_row_chunks(var, co, 1, stat_threads, |c0, vchunk| {
            for row in x.chunks_exact(co) {
                for (j, v) in vchunk.iter_mut().enumerate() {
                    let d = row[c0 + j] as f64 - mean[c0 + j];
                    *v += d * d;
                }
            }
        });
    }
    for v in var.iter_mut() {
        *v /= n as f64;
    }
    tape.inv_std.clear();
    tape.inv_std
        .extend(var.iter().map(|&v| 1.0 / ((v as f32 + BN_EPS).sqrt())));
    tape.xhat.clear();
    tape.xhat.resize(x.len(), 0.0);
    y.clear();
    y.resize(x.len(), 0.0);
    {
        let (mean, inv_std) = (&*mean, &tape.inv_std);
        let norm_threads = gate_threads(threads, 2 * x.len() as u64);
        par_row_chunks_zip(&mut tape.xhat, y, n, co, co, norm_threads, |i0, xh, yc| {
            for (r, (xh_row, y_row)) in
                xh.chunks_exact_mut(co).zip(yc.chunks_exact_mut(co)).enumerate()
            {
                let row = &x[(i0 + r) * co..(i0 + r + 1) * co];
                for c in 0..co {
                    let v = (row[c] - mean[c] as f32) * inv_std[c];
                    xh_row[c] = v;
                    y_row[c] = gamma[c] * v + beta[c];
                }
            }
        });
    }
    new_mean.clear();
    new_var.clear();
    for c in 0..co {
        new_mean.push(BN_MOMENTUM * run_mean[c] + (1.0 - BN_MOMENTUM) * mean[c] as f32);
        new_var.push(BN_MOMENTUM * run_var[c] + (1.0 - BN_MOMENTUM) * var[c] as f32);
    }
}

/// Per-channel Σx (f64) over rows `[r0, r1)` of an `n × co` buffer —
/// one canonical chunk's partial of the sync-BN mean reduction
/// (DESIGN.md §14).  Channel-sharded like [`bn_forward_train`]'s mean
/// pass; each channel's sum runs rows-ascending, so the full range
/// reproduces the whole-batch pass bit-for-bit.
pub fn bn_col_sums(x: &[f32], co: usize, r0: usize, r1: usize, threads: usize, out: &mut [f64]) {
    assert_eq!(out.len(), co);
    assert!(r0 <= r1 && r1 * co <= x.len());
    out.fill(0.0);
    let stat_threads = gate_threads(threads, 2 * (r1 - r0) as u64 * co as u64).min(co.max(1));
    par_row_chunks(out, co, 1, stat_threads, |c0, mchunk| {
        for row in x[r0 * co..r1 * co].chunks_exact(co) {
            for (m, &v) in mchunk.iter_mut().zip(&row[c0..c0 + mchunk.len()]) {
                *m += v as f64;
            }
        }
    });
}

/// Per-channel Σ(x − mean)² (f64) over rows `[r0, r1)` — one chunk's
/// partial of the sync-BN variance reduction (`mean` is the combined
/// global mean, already divided).
pub fn bn_col_sqdev_sums(
    x: &[f32],
    co: usize,
    mean: &[f64],
    r0: usize,
    r1: usize,
    threads: usize,
    out: &mut [f64],
) {
    assert_eq!(out.len(), co);
    assert!(r0 <= r1 && r1 * co <= x.len());
    out.fill(0.0);
    let stat_threads = gate_threads(threads, 2 * (r1 - r0) as u64 * co as u64).min(co.max(1));
    par_row_chunks(out, co, 1, stat_threads, |c0, vchunk| {
        for row in x[r0 * co..r1 * co].chunks_exact(co) {
            for (j, v) in vchunk.iter_mut().enumerate() {
                let d = row[c0 + j] as f64 - mean[c0 + j];
                *v += d * d;
            }
        }
    });
}

/// Normalize with externally supplied (global) moments: fills x̂ and
/// y = γ·x̂ + β.  Row-sharded; purely element-wise given the moments,
/// so bit-identical at any thread count.  `inv_std` comes from
/// [`bn_inv_std`].
#[allow(clippy::too_many_arguments)]
pub fn bn_normalize(
    x: &[f32],
    co: usize,
    mean: &[f64],
    inv_std: &[f32],
    gamma: &[f32],
    beta: &[f32],
    threads: usize,
    xhat: &mut [f32],
    y: &mut [f32],
) {
    let n = x.len() / co;
    assert_eq!(x.len(), n * co);
    assert_eq!(xhat.len(), x.len());
    assert_eq!(y.len(), x.len());
    let norm_threads = gate_threads(threads, 2 * x.len() as u64);
    par_row_chunks_zip(xhat, y, n, co, co, norm_threads, |i0, xh, yc| {
        for (r, (xh_row, y_row)) in xh.chunks_exact_mut(co).zip(yc.chunks_exact_mut(co)).enumerate()
        {
            let row = &x[(i0 + r) * co..(i0 + r + 1) * co];
            for c in 0..co {
                let v = (row[c] - mean[c] as f32) * inv_std[c];
                xh_row[c] = v;
                y_row[c] = gamma[c] * v + beta[c];
            }
        }
    });
}

/// Per-channel inverse standard deviation from an f64 variance vector —
/// the exact expression [`bn_forward_train`] uses.
pub fn bn_inv_std(var: &[f64], inv_std: &mut Vec<f32>) {
    inv_std.clear();
    inv_std.extend(var.iter().map(|&v| 1.0 / ((v as f32 + BN_EPS).sqrt())));
}

/// Per-channel (Σdy, Σdy·x̂) f64 partials over rows `[r0, r1)` — one
/// chunk's partial of the BN backward reductions.  Channel-sharded in
/// lockstep like [`bn_backward_train`]'s sum pass.
#[allow(clippy::too_many_arguments)]
pub fn bn_backward_col_sums(
    dy: &[f32],
    xhat: &[f32],
    co: usize,
    r0: usize,
    r1: usize,
    threads: usize,
    sum_dy: &mut [f64],
    sum_dyxh: &mut [f64],
) {
    assert_eq!(sum_dy.len(), co);
    assert_eq!(sum_dyxh.len(), co);
    assert!(r0 <= r1 && r1 * co <= dy.len());
    sum_dy.fill(0.0);
    sum_dyxh.fill(0.0);
    let stat_threads = gate_threads(threads, 2 * (r1 - r0) as u64 * co as u64).min(co.max(1));
    par_row_chunks_zip(sum_dy, sum_dyxh, co, 1, 1, stat_threads, |c0, sa, sb| {
        for (i, row) in dy[r0 * co..r1 * co].chunks_exact(co).enumerate() {
            for j in 0..sa.len() {
                let c = c0 + j;
                sa[j] += row[c] as f64;
                sb[j] += row[c] as f64 * xhat[(r0 + i) * co + c] as f64;
            }
        }
    });
}

/// BN backward dx pass with externally supplied (global) sums:
/// dx = γ·σ⁻¹·(dy − Σdy/n − x̂·Σdy·x̂/n), where `inv_n = 1/n` counts the
/// *global* batch rows the statistics were computed over.  Row-sharded
/// exactly like [`bn_backward_train`]'s dx pass.
#[allow(clippy::too_many_arguments)]
pub fn bn_backward_dx(
    dy: &[f32],
    xhat: &[f32],
    inv_std: &[f32],
    gamma: &[f32],
    sum_dy: &[f64],
    sum_dyxh: &[f64],
    inv_n: f32,
    threads: usize,
    dx: &mut Vec<f32>,
) {
    let co = gamma.len();
    let n = dy.len() / co;
    assert_eq!(dy.len(), n * co);
    dx.clear();
    dx.resize(dy.len(), 0.0);
    let row_threads = gate_threads(threads, 2 * dy.len() as u64);
    par_row_chunks(dx, n, co, row_threads, |i0, chunk| {
        for (r, drow) in chunk.chunks_exact_mut(co).enumerate() {
            let i = i0 + r;
            let row = &dy[i * co..(i + 1) * co];
            for c in 0..co {
                let term = row[c]
                    - inv_n * sum_dy[c] as f32
                    - xhat[i * co + c] * inv_n * sum_dyxh[c] as f32;
                drow[c] = gamma[c] * inv_std[c] * term;
            }
        }
    });
}

/// Eval-mode BN with running statistics (no tape).
pub fn bn_forward_eval(
    x: &[f32],
    co: usize,
    gamma: &[f32],
    beta: &[f32],
    run_mean: &[f32],
    run_var: &[f32],
    y: &mut Vec<f32>,
) {
    y.clear();
    y.resize(x.len(), 0.0);
    let mut scale = vec![0f32; co];
    let mut bias = vec![0f32; co];
    for c in 0..co {
        let g = gamma[c] / (run_var[c] + BN_EPS).sqrt();
        scale[c] = g;
        bias[c] = beta[c] - g * run_mean[c];
    }
    for (yrow, xrow) in y.chunks_exact_mut(co).zip(x.chunks_exact(co)) {
        for c in 0..co {
            yrow[c] = scale[c] * xrow[c] + bias[c];
        }
    }
}

/// Train-mode BN backward *through the batch statistics*:
/// dx = γ·σ⁻¹·(dy − mean(dy) − x̂·mean(dy·x̂)); dγ += Σ dy·x̂; dβ += Σ dy.
/// The two per-channel sums shard over channel ranges (rows-ascending
/// per channel, as in the forward); the dx pass shards over rows.
#[allow(clippy::too_many_arguments)]
pub fn bn_backward_train(
    dy: &[f32],
    co: usize,
    gamma: &[f32],
    tape: &BnTape,
    threads: usize,
    dx: &mut Vec<f32>,
    dgamma: &mut [f32],
    dbeta: &mut [f32],
    scratch: &mut BnScratch,
) {
    let n = dy.len() / co;
    let BnScratch { a: sum_dy, b: sum_dyxh } = scratch;
    sum_dy.clear();
    sum_dy.resize(co, 0.0);
    sum_dyxh.clear();
    sum_dyxh.resize(co, 0.0);
    let stat_threads = gate_threads(threads, 2 * dy.len() as u64).min(co);
    {
        let xhat = &tape.xhat;
        par_row_chunks_zip(sum_dy, sum_dyxh, co, 1, 1, stat_threads, |c0, sa, sb| {
            for (i, row) in dy.chunks_exact(co).enumerate() {
                for j in 0..sa.len() {
                    let c = c0 + j;
                    sa[j] += row[c] as f64;
                    sb[j] += row[c] as f64 * xhat[i * co + c] as f64;
                }
            }
        });
    }
    for c in 0..co {
        dgamma[c] += sum_dyxh[c] as f32;
        dbeta[c] += sum_dy[c] as f32;
    }
    let inv_n = 1.0 / n as f32;
    dx.clear();
    dx.resize(dy.len(), 0.0);
    let (sum_dy, sum_dyxh) = (&*sum_dy, &*sum_dyxh);
    let row_threads = gate_threads(threads, 2 * dy.len() as u64);
    par_row_chunks(dx, n, co, row_threads, |i0, chunk| {
        for (r, drow) in chunk.chunks_exact_mut(co).enumerate() {
            let i = i0 + r;
            let row = &dy[i * co..(i + 1) * co];
            for c in 0..co {
                let term = row[c]
                    - inv_n * sum_dy[c] as f32
                    - tape.xhat[i * co + c] * inv_n * sum_dyxh[c] as f32;
                drow[c] = gamma[c] * tape.inv_std[c] * term;
            }
        }
    });
}

/// Global average pool over each image's `n = oh·ow` positions:
/// (B·n) × co activations → B × co pooled features.
pub fn gap_forward(x: &[f32], batch: usize, n: usize, co: usize, pooled: &mut Vec<f32>) {
    assert_eq!(x.len(), batch * n * co);
    pooled.clear();
    pooled.resize(batch * co, 0.0);
    for b in 0..batch {
        let prow = &mut pooled[b * co..(b + 1) * co];
        for j in 0..n {
            let row = &x[(b * n + j) * co..(b * n + j + 1) * co];
            for (p, &v) in prow.iter_mut().zip(row) {
                *p += v;
            }
        }
        for p in prow.iter_mut() {
            *p /= n as f32;
        }
    }
}

/// GAP backward: broadcast dpooled/n over the positions.
pub fn gap_backward(dpooled: &[f32], batch: usize, n: usize, co: usize, dx: &mut Vec<f32>) {
    dx.clear();
    dx.resize(batch * n * co, 0.0);
    let inv_n = 1.0 / n as f32;
    for b in 0..batch {
        let prow = &dpooled[b * co..(b + 1) * co];
        for j in 0..n {
            let row = &mut dx[(b * n + j) * co..(b * n + j + 1) * co];
            for (d, &g) in row.iter_mut().zip(prow) {
                *d = g * inv_n;
            }
        }
    }
}

/// logits = pooled · W + b, W (in, classes) row-major.
pub fn fc_forward(
    pooled: &[f32],
    batch: usize,
    inf: usize,
    classes: usize,
    w: &[f32],
    b: &[f32],
    logits: &mut Vec<f32>,
) {
    logits.clear();
    logits.resize(batch * classes, 0.0);
    for bi in 0..batch {
        let lrow = &mut logits[bi * classes..(bi + 1) * classes];
        lrow.copy_from_slice(b);
        let prow = &pooled[bi * inf..(bi + 1) * inf];
        for (c, &p) in prow.iter().enumerate() {
            if p == 0.0 {
                continue;
            }
            let wrow = &w[c * classes..(c + 1) * classes];
            for (l, &wv) in lrow.iter_mut().zip(wrow) {
                *l += p * wv;
            }
        }
    }
}

/// FC backward: dW += pooledᵀ·dlogits, db += Σ dlogits, dpooled = dlogits·Wᵀ.
#[allow(clippy::too_many_arguments)]
pub fn fc_backward(
    dlogits: &[f32],
    pooled: &[f32],
    batch: usize,
    inf: usize,
    classes: usize,
    w: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    dpooled: &mut Vec<f32>,
) {
    dpooled.clear();
    dpooled.resize(batch * inf, 0.0);
    for bi in 0..batch {
        let drow = &dlogits[bi * classes..(bi + 1) * classes];
        for (d, &g) in db.iter_mut().zip(drow) {
            *d += g;
        }
        let prow = &pooled[bi * inf..(bi + 1) * inf];
        let dprow = &mut dpooled[bi * inf..(bi + 1) * inf];
        for c in 0..inf {
            let wrow = &w[c * classes..(c + 1) * classes];
            let dwrow = &mut dw[c * classes..(c + 1) * classes];
            let p = prow[c];
            let mut acc = 0f32;
            for i in 0..classes {
                dwrow[i] += p * drow[i];
                acc += wrow[i] * drow[i];
            }
            dprow[c] = acc;
        }
    }
}

/// Row-wise softmax probabilities (max-subtracted for stability).
pub fn softmax_rows(logits: &[f32], batch: usize, classes: usize, probs: &mut Vec<f32>) {
    probs.clear();
    probs.resize(batch * classes, 0.0);
    for b in 0..batch {
        let row = &logits[b * classes..(b + 1) * classes];
        let prow = &mut probs[b * classes..(b + 1) * classes];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0f32;
        for (p, &l) in prow.iter_mut().zip(row) {
            *p = (l - m).exp();
            z += *p;
        }
        for p in prow.iter_mut() {
            *p /= z;
        }
    }
}

/// Mean softmax cross-entropy with integer labels (`layers.cross_entropy`).
pub fn cross_entropy(logits: &[f32], labels: &[i32], classes: usize) -> f32 {
    let batch = labels.len();
    let mut total = 0f64;
    for b in 0..batch {
        let row = &logits[b * classes..(b + 1) * classes];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = m + row.iter().map(|&l| (l - m).exp()).sum::<f32>().ln();
        total += (lse - row[labels[b] as usize]) as f64;
    }
    (total / batch as f64) as f32
}

/// KL(teacher ‖ student) averaged over the batch (`layers.distill_loss`).
pub fn distill_loss(logits: &[f32], teacher: &[f32], batch: usize, classes: usize) -> f32 {
    let mut ps = Vec::new();
    let mut pt = Vec::new();
    softmax_rows(logits, batch, classes, &mut ps);
    softmax_rows(teacher, batch, classes, &mut pt);
    let mut total = 0f64;
    for i in 0..batch * classes {
        if pt[i] > 0.0 {
            total += (pt[i] as f64) * ((pt[i] as f64).ln() - (ps[i] as f64).max(1e-30).ln());
        }
    }
    (total / batch as f64) as f32
}

/// Number of correct top-1 predictions.
pub fn correct_count(logits: &[f32], labels: &[i32], classes: usize) -> f32 {
    labels
        .iter()
        .enumerate()
        .filter(|(b, &lab)| {
            let row = &logits[b * classes..(b + 1) * classes];
            let am = row
                .iter()
                .enumerate()
                .max_by(|a, c| a.1.partial_cmp(c.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            am == lab as usize
        })
        .count() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bn_train_normalizes_and_backprops_zero_for_uniform_dy() {
        // x with per-channel mean 2 / values {1,3}; gamma=1, beta=0.
        let x = vec![1.0f32, 3.0, 3.0, 1.0]; // co=1, n=4
        let (mut y, mut tape) = (Vec::new(), BnTape::default());
        let (mut nm, mut nv) = (Vec::new(), Vec::new());
        let mut bns = BnScratch::default();
        bn_forward_train(
            &x, 1, &[1.0], &[0.0], &[0.0], &[1.0], 1, &mut y, &mut tape, &mut nm, &mut nv,
            &mut bns,
        );
        let mean: f32 = y.iter().sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((nm[0] - 0.1 * 2.0).abs() < 1e-6); // 0.9·0 + 0.1·2
        // constant upstream gradient is annihilated by the mean-subtraction
        let dy = vec![0.7f32; 4];
        let mut dx = Vec::new();
        let (mut dg, mut db) = (vec![0f32], vec![0f32]);
        bn_backward_train(&dy, 1, &[1.0], &tape, 1, &mut dx, &mut dg, &mut db, &mut bns);
        assert!(dx.iter().all(|d| d.abs() < 1e-6), "{dx:?}");
        assert!((db[0] - 2.8).abs() < 1e-6);
    }

    #[test]
    fn conv_backward_x_is_adjoint_of_forward() {
        // <conv(x), dy> == <x, conv_backward_x(dy)> — the defining
        // property of the transpose, checked on random small shapes.
        let mut rng = crate::util::Rng::new(0xAD70);
        for _ in 0..10 {
            let (b, h, w, ci, co, k) = (2usize, 5usize, 4usize, 3usize, 2usize, 3usize);
            let stride = 1 + rng.below(2);
            let x: Vec<f32> = (0..b * h * w * ci).map(|_| rng.normal()).collect();
            let wts: Vec<f32> = (0..k * k * ci * co).map(|_| rng.normal()).collect();
            let mut p = Patches::empty();
            patches_of(&x, b, h, w, ci, k, stride, &mut p);
            let mut y = Vec::new();
            conv_forward(&p, &wts, co, 1, &mut y);
            let dy: Vec<f32> = (0..y.len()).map(|_| rng.normal()).collect();
            let mut dx = vec![0f32; x.len()];
            conv_backward_x(&dy, &wts, b, h, w, ci, co, k, stride, 1, &mut dx);
            let lhs: f64 = y.iter().zip(&dy).map(|(&a, &g)| (a * g) as f64).sum();
            let rhs: f64 = x.iter().zip(&dx).map(|(&a, &g)| (a * g) as f64).sum();
            assert!(
                (lhs - rhs).abs() < 1e-2 * lhs.abs().max(1.0),
                "adjoint mismatch {lhs} vs {rhs}"
            );
        }
    }

    #[test]
    fn conv_backward_w_matches_finite_difference() {
        let mut rng = crate::util::Rng::new(0xD1FF);
        let (b, h, w, ci, co, k, stride) = (1usize, 4usize, 4usize, 2usize, 2usize, 3usize, 1usize);
        let x: Vec<f32> = (0..b * h * w * ci).map(|_| rng.normal()).collect();
        let wts: Vec<f32> = (0..k * k * ci * co).map(|_| 0.5 * rng.normal()).collect();
        let dy: Vec<f32> = (0..b * h * w * co).map(|_| rng.normal()).collect();
        let mut p = Patches::empty();
        patches_of(&x, b, h, w, ci, k, stride, &mut p);
        let mut dw = vec![0f32; wts.len()];
        conv_backward_w(&p, &dy, co, 1, &mut dw);
        let loss = |wv: &[f32]| -> f64 {
            let mut y = Vec::new();
            conv_forward(&p, wv, co, 1, &mut y);
            y.iter().zip(&dy).map(|(&a, &g)| (a * g) as f64).sum()
        };
        let eps = 1e-2f32;
        for idx in [0usize, 3, 7, wts.len() - 1] {
            let mut wp = wts.clone();
            wp[idx] += eps;
            let mut wm = wts.clone();
            wm[idx] -= eps;
            let num = (loss(&wp) - loss(&wm)) / (2.0 * eps as f64);
            assert!(
                (num - dw[idx] as f64).abs() < 1e-2 * num.abs().max(1.0),
                "dw[{idx}] {num} vs {}",
                dw[idx]
            );
        }
    }

    #[test]
    fn split_bn_primitives_reproduce_monolithic_kernels_on_full_range() {
        // The ctx-aware graph path computes BN through the split
        // primitives; at one chunk covering the whole batch they must
        // be bit-identical to the monolithic kernels (serial parity).
        let mut rng = crate::util::Rng::new(0xB127);
        let (n, co) = (37usize, 5usize);
        let x: Vec<f32> = (0..n * co).map(|_| rng.normal() * 2.0 + 0.3).collect();
        let gamma: Vec<f32> = (0..co).map(|_| 1.0 + 0.1 * rng.normal()).collect();
        let beta: Vec<f32> = (0..co).map(|_| 0.2 * rng.normal()).collect();
        let (rm, rv) = (vec![0.1f32; co], vec![0.9f32; co]);

        let (mut y, mut tape) = (Vec::new(), BnTape::default());
        let (mut nm, mut nv) = (Vec::new(), Vec::new());
        let mut bns = BnScratch::default();
        bn_forward_train(&x, co, &gamma, &beta, &rm, &rv, 1, &mut y, &mut tape, &mut nm, &mut nv, &mut bns);

        let mut mean = vec![0f64; co];
        bn_col_sums(&x, co, 0, n, 1, &mut mean);
        for m in mean.iter_mut() {
            *m /= n as f64;
        }
        let mut var = vec![0f64; co];
        bn_col_sqdev_sums(&x, co, &mean, 0, n, 1, &mut var);
        for v in var.iter_mut() {
            *v /= n as f64;
        }
        let mut inv_std = Vec::new();
        bn_inv_std(&var, &mut inv_std);
        assert_eq!(inv_std, tape.inv_std);
        let mut xhat2 = vec![0f32; x.len()];
        let mut y2 = vec![0f32; x.len()];
        bn_normalize(&x, co, &mean, &inv_std, &gamma, &beta, 1, &mut xhat2, &mut y2);
        assert_eq!(xhat2, tape.xhat);
        assert_eq!(y2, y);
        for c in 0..co {
            assert_eq!(BN_MOMENTUM * rm[c] + (1.0 - BN_MOMENTUM) * mean[c] as f32, nm[c]);
            assert_eq!(BN_MOMENTUM * rv[c] + (1.0 - BN_MOMENTUM) * var[c] as f32, nv[c]);
        }

        // backward parity
        let dy: Vec<f32> = (0..n * co).map(|_| rng.normal()).collect();
        let mut dx = Vec::new();
        let (mut dg, mut db) = (vec![0f32; co], vec![0f32; co]);
        bn_backward_train(&dy, co, &gamma, &tape, 1, &mut dx, &mut dg, &mut db, &mut bns);
        let (mut sdy, mut sdyxh) = (vec![0f64; co], vec![0f64; co]);
        bn_backward_col_sums(&dy, &tape.xhat, co, 0, n, 1, &mut sdy, &mut sdyxh);
        for c in 0..co {
            assert_eq!(sdyxh[c] as f32, dg[c]);
            assert_eq!(sdy[c] as f32, db[c]);
        }
        let mut dx2 = Vec::new();
        bn_backward_dx(
            &dy, &tape.xhat, &tape.inv_std, &gamma, &sdy, &sdyxh, 1.0 / n as f32, 1, &mut dx2,
        );
        assert_eq!(dx2, dx);
    }

    #[test]
    fn conv_backward_w_cols_partials_sum_to_full_and_full_matches_whole() {
        let mut rng = crate::util::Rng::new(0xC015);
        let (b, h, w, ci, co, k, stride) = (4usize, 5usize, 5usize, 2usize, 3usize, 3usize, 1usize);
        let x: Vec<f32> = (0..b * h * w * ci).map(|_| rng.normal()).collect();
        let dy: Vec<f32> = (0..b * h * w * co).map(|_| rng.normal()).collect();
        let mut p = Patches::empty();
        patches_of(&x, b, h, w, ci, k, stride, &mut p);
        let mut full = vec![0f32; k * k * ci * co];
        conv_backward_w(&p, &dy, co, 1, &mut full);
        let mut ranged = vec![0f32; full.len()];
        conv_backward_w_cols(&p, &dy, co, 0, p.n, 1, &mut ranged);
        assert_eq!(ranged, full, "full-range cols variant must be bit-identical");
        // chunked partials combined in order approximate the serial sum
        let npos = p.n / b;
        let mut combined = vec![0f32; full.len()];
        for chunk in 0..b {
            let mut part = vec![0f32; full.len()];
            conv_backward_w_cols(&p, &dy, co, chunk * npos, (chunk + 1) * npos, 1, &mut part);
            for (c, &v) in combined.iter_mut().zip(&part) {
                *c += v;
            }
        }
        for (a, b_) in combined.iter().zip(&full) {
            assert!((a - b_).abs() <= 1e-4 * b_.abs().max(1.0), "{a} vs {b_}");
        }
    }

    #[test]
    fn ce_and_softmax_consistency() {
        let logits = vec![1.0f32, 2.0, 3.0, 0.0, 0.0, 0.0];
        let labels = vec![2i32, 1];
        let loss = cross_entropy(&logits, &labels, 3);
        let mut probs = Vec::new();
        softmax_rows(&logits, 2, 3, &mut probs);
        let manual = -((probs[2]).ln() + (probs[4]).ln()) / 2.0;
        assert!((loss - manual).abs() < 1e-5);
        assert_eq!(correct_count(&logits, &labels, 3), 1.0);
    }
}
