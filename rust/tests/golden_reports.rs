//! Golden-file tests for the report layer: the rendered Markdown/CSV of
//! table1/table3/table4/ablation — titles, headers, alignment, and the
//! shared cell formatters — are pinned against committed fixtures in
//! `tests/golden/`, so formatting regressions show up as diffs instead
//! of silently corrupting EXPERIMENTS.md regenerations.
//!
//! Regenerate after an intentional change with:
//! `UPDATE_GOLDEN=1 cargo test --test golden_reports`

use std::path::PathBuf;

use ebs::report::table_fmt::{mflops, pct, saving, Table};
use ebs::report::{ablation, table1, table3, table4};

fn check_or_update(name: &str, content: &str) {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden").join(name);
    if std::env::var("UPDATE_GOLDEN").ok().as_deref() == Some("1") {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, content).unwrap();
        eprintln!("[golden] wrote {}", path.display());
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!(
            "missing golden fixture {} ({e}); regenerate with UPDATE_GOLDEN=1",
            path.display()
        )
    });
    assert_eq!(
        content,
        want,
        "rendered output for {name} drifted from the committed fixture; \
         if intentional, regenerate with UPDATE_GOLDEN=1 cargo test --test golden_reports"
    );
}

/// Representative Table 1 content (fixed values, production formatters).
fn table1_sample() -> Table {
    let mut t = table1::skeleton("resnet20_synth");
    t.row(vec!["Full Prec.".into(), "32-bit".into(), pct(0.9012), mflops(41.22), "1.00x".into()]);
    t.row(vec![
        "Uniform QNN".into(),
        "4 bits".into(),
        pct(0.8907),
        mflops(10.36),
        saving(3.98),
    ]);
    t.row(vec!["EBS-Det".into(), "flexible".into(), pct(0.8984), mflops(6.21), saving(6.64)]);
    t.row(vec![
        "Random Search".into(),
        "flexible".into(),
        pct(0.8733),
        mflops(6.42),
        saving(6.42),
    ]);
    t
}

#[test]
fn golden_table1_markdown_and_csv() {
    let t = table1_sample();
    check_or_update("table1.md", &t.to_markdown());
    check_or_update("table1.csv", &t.to_csv());
}

#[test]
fn golden_fig5_markdown() {
    let mut t = table1::fig5_skeleton("resnet20_synth");
    t.row(vec!["fp32".into(), "41.220".into(), "0.9012".into()]);
    t.row(vec!["uniform4".into(), "10.360".into(), "0.8907".into()]);
    t.row(vec!["ebs-det".into(), "6.210".into(), "0.8984".into()]);
    check_or_update("fig5.md", &t.to_markdown());
}

#[test]
fn golden_table3_markdown() {
    let mut t = table3::skeleton(10);
    t.row(vec![
        "resnet8_tiny [native]".into(),
        "16".into(),
        "Uniform QNN".into(),
        "1.92".into(),
        "0.192".into(),
        "0.41".into(),
        "1.2".into(),
        "0.09".into(),
    ]);
    t.row(vec![
        "resnet8_tiny [native]".into(),
        "16".into(),
        "EBS".into(),
        "2.48".into(),
        "0.248".into(),
        "0.44".into(),
        "1.2".into(),
        "0.09".into(),
    ]);
    t.row(vec![
        "resnet8_tiny [native]".into(),
        "16".into(),
        "DNAS".into(),
        "11.07".into(),
        "1.107".into(),
        "0.96".into(),
        "5.8".into(),
        "0.46".into(),
    ]);
    check_or_update("table3.md", &t.to_markdown());
}

#[test]
fn golden_table4_markdown() {
    let mut t = table4::skeleton();
    t.row(vec![
        "3".into(),
        "64".into(),
        "64".into(),
        "1".into(),
        "1.84".into(),
        "3.61".into(),
        "1.96x".into(),
        "7.22".into(),
    ]);
    t.row(vec![
        "3".into(),
        "128".into(),
        "128".into(),
        "1".into(),
        "1.77".into(),
        "3.52".into(),
        "1.99x".into(),
        "7.04".into(),
    ]);
    t.row(vec![
        "Bi-Real-18 body".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "30.1".into(),
        "59.8".into(),
        "1.99x".into(),
        "-".into(),
    ]);
    check_or_update("table4.md", &t.to_markdown());

    let mut sweep = table4::sweep_skeleton(8);
    sweep.row(vec![
        "3x3 64→64 @14²".into(),
        "2,2".into(),
        "8".into(),
        "0.412".into(),
        "0.287".into(),
        "0.106".into(),
        "3.89x".into(),
    ]);
    check_or_update("table4c.md", &sweep.to_markdown());
}

#[test]
fn golden_ablation_markdown() {
    let mut t = ablation::skeleton("resnet8_tiny", 0.16);
    t.row(ablation::row_cells(0.05, false, 0.3012, 0.3371, 0.16, 0.4012, 4.21, 4.63));
    t.row(ablation::row_cells(2.0, true, 0.1581, 0.1703, 0.16, 0.3807, 2.84, 3.12));
    check_or_update("ablation_lambda.md", &t.to_markdown());
}
