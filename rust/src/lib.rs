//! EBS: Efficient Bitwidth Search for practical mixed precision neural
//! networks — Rust L3 coordinator, BD deployment engine, and experiment
//! harness (see DESIGN.md for the paper→module map).
//!
//! Layering:
//! * [`runtime`] — `Backend` trait + engine front-end: the PJRT bridge
//!   for AOT artifacts built by `python/compile/aot.py`, with per-graph
//!   dispatch and profiling.
//! * [`native`] — pure-Rust CPU backend: interprets the same step
//!   graphs (forward + hand-written backward with STE) so Algorithm 1
//!   runs end-to-end without artifacts or a PJRT runtime.
//! * [`coordinator`] — Algorithm 1 (bilevel search), training drivers,
//!   FLOPs model, bitwidth selection, schedules.
//! * [`bd`] — Binary Decomposition inference engine (Eq. 12-14) for
//!   generic CPUs: bitplane packing + AND/popcount GEMM + shift-add.
//! * [`kernels`] — shared threaded-kernel substrate: deterministic
//!   row-partitioned `std::thread::scope` dispatch used by both the BD
//!   GEMM and the native training kernels (DESIGN.md §12).
//! * [`exec`] — data-parallel sharded step executor: shard planner,
//!   replica pool, sync-BN moment hub, and the deterministic
//!   chunk-ordered all-reduce that keeps same-seed runs bit-identical
//!   at any shard count (DESIGN.md §14).
//! * [`serve`] — concurrent micro-batching serve layer over the BD
//!   engine: bounded request queue, dynamic coalescer, worker pool,
//!   length-prefixed TCP/stdin front-end (DESIGN.md §13).
//! * [`data`] — synthetic dataset substrate + batching.
//! * [`baselines`] — uniform precision, random search, DNAS supernet.
//! * [`report`] — regenerators for every table/figure in the paper.
//! * [`fuzzing`] — shared fuzz-target bodies: the libFuzzer harness in
//!   `rust/fuzz/` and the tier-1 corpus-replay tests drive identical
//!   code (DESIGN.md §16).

pub mod baselines;
pub mod bd;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod fuzzing;
pub mod kernels;
pub mod models;
pub mod native;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod util;
