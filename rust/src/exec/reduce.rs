//! Deterministic all-reduce over gradient leaves (DESIGN.md §14).
//!
//! The sharded backward produces one [`Grads`] per canonical chunk
//! (dense vectors keyed by the same `state/...` paths a
//! [`crate::runtime::StateVec`] holds, plus the per-layer branch
//! coefficient rows).  The combine is a plain left-to-right sum over
//! chunk partials in global chunk order, executed on one thread: the
//! association is fixed by the chunking alone, so the result is
//! bit-identical at any shard count.  HashMap iteration order is
//! irrelevant here — distinct leaves have independent accumulators, and
//! within a leaf the parts arrive in chunk order.
//!
//! Steady state performs no allocation: the accumulator's leaves are
//! grown on the first step and zeroed-then-summed afterwards.

use crate::native::graph::Grads;

/// Zero `total`'s persistent leaves and size its coefficient rows —
/// the accumulator identity for [`accumulate_grads`].  Delegates to
/// `Grads::begin_step` so the reset invariant is defined once.
pub fn zero_grads(total: &mut Grads, layers: usize, n_bits: usize) {
    total.begin_step(layers, n_bits);
}

/// `total += part`, element-wise over every leaf and coefficient row.
/// Call once per chunk in global chunk order.
pub fn accumulate_grads(total: &mut Grads, part: &Grads) {
    for (path, src) in &part.by_path {
        match total.by_path.get_mut(path) {
            Some(dst) => {
                debug_assert_eq!(dst.len(), src.len(), "grad leaf '{path}' size drift");
                for (d, &v) in dst.iter_mut().zip(src) {
                    *d += v;
                }
            }
            None => {
                total.by_path.insert(path.clone(), src.clone());
            }
        }
    }
    for (dst, src) in total.dcw.iter_mut().zip(&part.dcw) {
        for (d, &v) in dst.iter_mut().zip(src) {
            *d += v;
        }
    }
    for (dst, src) in total.dcx.iter_mut().zip(&part.dcx) {
        for (d, &v) in dst.iter_mut().zip(src) {
            *d += v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn part(scale: f32) -> Grads {
        Grads {
            by_path: [("state/params/w".to_string(), vec![scale, 2.0 * scale])]
                .into_iter()
                .collect(),
            dcw: vec![vec![scale; 3]],
            dcx: vec![vec![-scale; 3]],
        }
    }

    #[test]
    fn combine_is_the_chunk_ordered_sum() {
        let mut total = Grads::default();
        zero_grads(&mut total, 1, 3);
        for p in [part(1.0), part(0.5), part(0.25)] {
            accumulate_grads(&mut total, &p);
        }
        assert_eq!(total.by_path["state/params/w"], vec![1.75, 3.5]);
        assert_eq!(total.dcw[0], vec![1.75; 3]);
        assert_eq!(total.dcx[0], vec![-1.75; 3]);
        // reuse: zeroing brings the accumulator back to identity
        zero_grads(&mut total, 1, 3);
        assert_eq!(total.by_path["state/params/w"], vec![0.0, 0.0]);
    }
}
