//! Baselines the paper compares against (Tables 1-3, Fig. 5-6):
//! uniform-precision QNNs, random bitwidth search, and the DNAS
//! supernet cost harness.

pub mod dnas;
pub mod random_search;
pub mod uniform;

pub use dnas::run_dnas_steps;
pub use random_search::run_random_search;
pub use uniform::run_uniform;
