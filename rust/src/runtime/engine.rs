//! Execution engine front-end: owns the manifest, dispatches to a
//! [`Backend`] (PJRT artifacts or the native Rust interpreter), and
//! keeps per-graph wall-clock accounting.
//!
//! PJRT path mirrors /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`.  Graphs are
//! compiled lazily on first use and cached for the process lifetime.
//!
//! The run protocol (DESIGN.md §7.1): the manifest lists each graph's
//! flattened inputs/outputs; leaves whose path starts with `state/` are
//! wired to the [`StateVec`], `in/...` leaves come from the per-call io
//! map, `out/...` leaves are returned as metrics.  The native backend
//! interprets the same graph names directly (DESIGN.md §11), so
//! `Engine::open` works — and the full pipeline runs — on machines with
//! neither artifacts nor a real PJRT runtime.

use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::backend::{Backend, BackendKind};
use super::manifest::{GraphSpec, Manifest};
use super::state::StateVec;
use super::tensor::Tensor;

/// Metrics returned by one graph execution.
pub type Metrics = HashMap<String, Tensor>;

/// Whether this build links a real PJRT backend.  The offline CI
/// workspace links the API stub at `rust/xla-stub` (DESIGN.md §3);
/// artifact-driven tests/benches (BD ↔ HLO parity at full fidelity)
/// check this and skip, while everything step-graph-shaped now runs on
/// the native backend instead.
pub fn backend_available() -> bool {
    xla::BACKEND_AVAILABLE
}

/// Scalar-metric convenience view.
pub fn metric_f32(m: &Metrics, key: &str) -> Result<f32> {
    m.get(key)
        .with_context(|| format!("metric '{key}' missing"))?
        .item_f32()
}

/// One model's execution engine: manifest + backend + profiling.
pub struct Engine {
    pub manifest: Manifest,
    backend: Box<dyn Backend>,
    /// Cumulative wall-clock spent inside `run` per graph (profiling).
    pub exec_time: HashMap<String, Duration>,
    pub exec_count: HashMap<String, u64>,
}

impl Engine {
    /// Open an engine for one model directory (e.g.
    /// `artifacts/resnet20_synth`) with `auto` backend resolution:
    /// PJRT when the real bindings and `manifest.json` are both
    /// present, the native interpreter otherwise (synthesizing the
    /// manifest from the model registry when no artifacts exist).
    pub fn open(dir: &Path) -> Result<Engine> {
        Engine::open_with(dir, BackendKind::Auto)
    }

    /// [`Engine::open`] with an explicit backend choice.
    pub fn open_with(dir: &Path, kind: BackendKind) -> Result<Engine> {
        let has_artifacts = dir.join("manifest.json").exists();
        if has_artifacts {
            let manifest = Manifest::load(dir)?;
            let use_pjrt = match kind {
                BackendKind::Pjrt => true,
                BackendKind::Native => false,
                BackendKind::Auto => backend_available(),
            };
            let backend: Box<dyn Backend> = if use_pjrt {
                Box::new(PjrtBackend::new()?)
            } else {
                Box::new(crate::native::NativeBackend::from_manifest(&manifest)?)
            };
            return Ok(Engine::from_parts(manifest, backend));
        }
        if kind == BackendKind::Pjrt {
            bail!(
                "backend 'pjrt' requested but {} has no manifest.json — run `make artifacts`",
                dir.display()
            );
        }
        let model = dir
            .file_name()
            .and_then(|s| s.to_str())
            .with_context(|| format!("cannot infer model name from {}", dir.display()))?;
        Engine::native(model)
    }

    /// Native engine straight from the model registry (no artifacts, no
    /// files touched): `ebs search --backend native`, CI integration
    /// tests, and any machine without a PJRT runtime.
    pub fn native(model: &str) -> Result<Engine> {
        let cfg = crate::native::models::lookup(model).with_context(|| {
            format!(
                "model '{model}' not in the native registry (known: {}); \
                 export artifacts for custom geometries",
                crate::native::models::registry_names().join(", ")
            )
        })?;
        let manifest = crate::native::models::synthesize_manifest(&cfg)?;
        let backend = Box::new(crate::native::NativeBackend::from_manifest(&manifest)?);
        Ok(Engine::from_parts(manifest, backend))
    }

    fn from_parts(manifest: Manifest, backend: Box<dyn Backend>) -> Engine {
        Engine {
            manifest,
            backend,
            exec_time: HashMap::new(),
            exec_count: HashMap::new(),
        }
    }

    /// Which backend this engine dispatches to ("pjrt" / "native").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Worker threads for the backend's kernels (`0` = machine
    /// parallelism; `run.threads`/`--threads`).  Bit-identical results
    /// at any count — a pure wall-clock knob.
    pub fn set_threads(&mut self, threads: usize) {
        self.backend.set_threads(threads);
    }

    /// Data-parallel sharding for the step graphs (`[search] shards` /
    /// `--shards`; DESIGN.md §14).  With a fixed chunk count, results
    /// are bit-identical at any shard count on backends that implement
    /// the sharded path (native); other backends run serially.
    pub fn set_shards(&mut self, spec: crate::exec::ShardSpec) {
        self.backend.set_shards(spec);
    }

    /// Swap the replica transport behind the sharded path (DESIGN.md
    /// §18; `--cluster`).  Fails on backends without one (pjrt).
    pub fn set_transport(
        &mut self,
        transport: Box<dyn crate::exec::ChunkTransport>,
    ) -> Result<()> {
        self.backend.set_transport(transport)
    }

    /// Register a dataset with the sharded path's transport so drivers
    /// can pass batches by example index (`*_src` io entries;
    /// DESIGN.md §18).  No-op on transports without remote residency.
    pub fn host_dataset(&mut self, id: u32, ds: &crate::data::Dataset) -> Result<()> {
        self.backend.host_dataset(id, ds)
    }

    /// Cumulative transport wire traffic (cluster mode); None when the
    /// configured transport has no wire.
    pub fn wire_stats(&self) -> Option<crate::exec::wire::WireTotals> {
        self.backend.wire_stats()
    }

    /// Compile (or fetch cached) a graph by name; no-op on native.
    pub fn prepare(&mut self, graph: &str) -> Result<()> {
        self.backend.prepare(&self.manifest, graph)
    }

    /// Fresh state from the init graph.
    pub fn init_state(&mut self, seed: i32) -> Result<StateVec> {
        self.backend.init_state(&self.manifest, seed)
    }

    /// Fresh DNAS supernet state (requires artifacts exported with --dnas).
    pub fn init_dnas_state(&mut self, seed: i32) -> Result<StateVec> {
        self.backend.init_dnas_state(&self.manifest, seed)
    }

    /// Execute one graph: wire state + io inputs, write back state
    /// outputs, return `out/...` metrics.  `exec_time` accumulates the
    /// backend-reported execution-only duration (compilation and input
    /// marshalling excluded — the pre-refactor profiling contract).
    pub fn run(
        &mut self,
        graph: &str,
        state: &mut StateVec,
        io: &[(String, Tensor)],
    ) -> Result<Metrics> {
        self.backend.prepare(&self.manifest, graph)?;
        let (metrics, dt) = self.backend.run(&self.manifest, graph, state, io)?;
        *self.exec_time.entry(graph.to_string()).or_default() += dt;
        *self.exec_count.entry(graph.to_string()).or_default() += 1;
        Ok(metrics)
    }

    /// [`Engine::run`] through the backend's sharded-step dispatch
    /// ([`Backend::run_sharded`]): same io protocol and profiling
    /// accounting, with the step fanned out over the replicas configured
    /// by [`Engine::set_shards`] (serial fallback on backends or graphs
    /// without a sharded lowering).
    pub fn run_sharded(
        &mut self,
        graph: &str,
        state: &mut StateVec,
        io: &[(String, Tensor)],
    ) -> Result<Metrics> {
        self.backend.prepare(&self.manifest, graph)?;
        let (metrics, dt) = self.backend.run_sharded(&self.manifest, graph, state, io)?;
        *self.exec_time.entry(graph.to_string()).or_default() += dt;
        *self.exec_count.entry(graph.to_string()).or_default() += 1;
        Ok(metrics)
    }

    /// Mean execution wall-clock for a graph, if it has run.
    pub fn mean_exec_time(&self, graph: &str) -> Option<Duration> {
        let total = self.exec_time.get(graph)?;
        let n = *self.exec_count.get(graph)? as u32;
        (n > 0).then(|| *total / n)
    }
}

/// The compiled-artifact backend (real `xla` bindings required; with
/// the offline stub every entry point fails fast with a self-describing
/// error — check [`backend_available`]).
pub struct PjrtBackend {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtBackend {
    pub fn new() -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtBackend { client, executables: HashMap::new() })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn init_state(&mut self, manifest: &Manifest, seed: i32) -> Result<StateVec> {
        let spec = manifest.state_spec.clone();
        let mut state = StateVec::zeros(&spec);
        let io = [("seed".to_string(), Tensor::scalar_i32(seed))];
        let (m, _) = self.run(manifest, "init", &mut state, &io)?;
        debug_assert!(m.is_empty());
        Ok(state)
    }

    fn init_dnas_state(&mut self, manifest: &Manifest, seed: i32) -> Result<StateVec> {
        let spec = manifest
            .dnas_state_spec
            .clone()
            .context("manifest has no dnas_state_spec; re-export with --dnas")?;
        let mut state = StateVec::zeros(&spec);
        let io = [("seed".to_string(), Tensor::scalar_i32(seed))];
        self.run(manifest, "dnas_init", &mut state, &io)?;
        Ok(state)
    }

    fn prepare(&mut self, manifest: &Manifest, graph: &str) -> Result<()> {
        if self.executables.contains_key(graph) {
            return Ok(());
        }
        let spec = manifest.graph(graph)?.clone();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file
                .to_str()
                .with_context(|| format!("non-utf8 path {:?}", spec.file))?,
        )
        .with_context(|| format!("parsing HLO text {}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile of graph '{graph}'"))?;
        eprintln!(
            "[engine] compiled {}/{} in {:.2}s",
            manifest.model,
            graph,
            t0.elapsed().as_secs_f64()
        );
        self.executables.insert(graph.to_string(), exe);
        Ok(())
    }

    fn run(
        &mut self,
        manifest: &Manifest,
        graph: &str,
        state: &mut StateVec,
        io: &[(String, Tensor)],
    ) -> Result<(Metrics, std::time::Duration)> {
        self.prepare(manifest, graph)?;
        let spec: &GraphSpec = manifest.graph(graph)?;
        let io_map: HashMap<&str, &Tensor> =
            io.iter().map(|(k, v)| (k.as_str(), v)).collect();

        let mut literals = Vec::with_capacity(spec.inputs.len());
        for leaf in &spec.inputs {
            let tensor = if leaf.path.starts_with("state/") {
                &state.tensors[state.idx(&leaf.path)?]
            } else if let Some(name) = leaf.path.strip_prefix("in/") {
                *io_map
                    .get(name)
                    .with_context(|| format!("graph '{graph}' needs input '{name}'"))?
            } else {
                bail!("unknown input role for path '{}'", leaf.path);
            };
            if tensor.shape() != leaf.shape.as_slice() {
                bail!(
                    "input '{}' shape {:?} != spec {:?}",
                    leaf.path,
                    tensor.shape(),
                    leaf.shape
                );
            }
            literals.push(tensor.to_literal()?);
        }

        // Execution-only region: device execute + root readback (input
        // marshalling above stays outside, as it always has).
        let exe = self.executables.get(graph).expect("prepared above");
        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing graph '{graph}'"))?;
        let root = result[0][0].to_literal_sync()?;
        let exec_dt = t0.elapsed();

        // Graphs are lowered with return_tuple=True → single tuple root.
        let leaves = root.to_tuple()?;
        if leaves.len() != spec.outputs.len() {
            bail!(
                "graph '{graph}' returned {} leaves, manifest says {}",
                leaves.len(),
                spec.outputs.len()
            );
        }
        let mut metrics = Metrics::new();
        for (leaf, lit) in spec.outputs.iter().zip(leaves.iter()) {
            let t = Tensor::from_literal(lit, leaf.dtype, &leaf.shape)
                .with_context(|| format!("reading output '{}'", leaf.path))?;
            if leaf.path.starts_with("state/") {
                let i = state.idx(&leaf.path)?;
                state.tensors[i] = t;
            } else if let Some(name) = leaf.path.strip_prefix("out/") {
                metrics.insert(name.to_string(), t);
            } else {
                bail!("unknown output role for path '{}'", leaf.path);
            }
        }
        Ok((metrics, exec_dt))
    }
}
