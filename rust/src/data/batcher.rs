//! Epoch-shuffled batch iterator over a [`Dataset`] — the *epoch*
//! batcher (`EpochBatcher`), as opposed to the serve layer's request
//! micro-batcher (`serve::batcher`): this one owns training-data order,
//! that one coalesces inference requests.  The rename keeps both
//! importable side by side from the sharded executor without aliases.
//!
//! Fixed batch size (artifacts are compiled for one batch shape).  The
//! iterator is a *stream of epoch permutations*: draws `[k·n, (k+1)·n)`
//! (n = dataset size) always form one complete permutation, so every
//! sample appears exactly once per `n` draws regardless of whether the
//! batch size divides `n`.  A batch that spans an epoch boundary is
//! additionally guaranteed duplicate-free: the next epoch's shuffle is
//! repaired so none of the indices already drawn into the partial batch
//! reappear before it completes.
//!
//! (The previous implementation prepended the carried tail to a fresh
//! full permutation, growing `order` beyond `n` — `batches_per_epoch()`
//! undercounted actual delivery and a tail sample could repeat within
//! the carried batch window.  Regression tests:
//! `every_sample_exactly_once_per_len_draws`, `no_duplicates_within_a_batch`.)

use anyhow::{ensure, Result};

use crate::runtime::Tensor;
use crate::util::Rng;

use super::synth::Dataset;

/// Resumable position in the permutation stream (DESIGN.md §14): the
/// current epoch permutation, the cursor into it, the epoch counter,
/// and the shuffle RNG state.  Restoring a cursor continues the draw
/// stream bit-exactly — O(1), no fast-forward replay of prior draws.
#[derive(Debug, Clone, PartialEq)]
pub struct BatcherCursor {
    pub order: Vec<usize>,
    pub pos: usize,
    pub epoch: usize,
    pub rng: [u64; 4],
}

/// Shuffled mini-batch source with a deterministic RNG.
///
/// **Sharding contract (DESIGN.md §14).**  The sharded step executor
/// consumes ONE global batcher and splits each drawn batch into
/// contiguous example ranges via `ShardPlan` — per-shard batchers (and
/// thus per-shard seed derivation) never exist, so the epoch guarantees
/// below (every sample exactly once per `len` draws, no duplicate
/// within a batch) hold for the union of the shards by construction,
/// and the draw stream is identical at any shard count.
pub struct EpochBatcher<'a> {
    ds: &'a Dataset,
    batch: usize,
    order: Vec<usize>,
    pos: usize,
    rng: Rng,
    pub epoch: usize,
}

/// Build the `{name}_src` io side-channel for a batch drawn by index:
/// `[dataset_id, idx0, idx1, …]` as f32 (exact for integers ≤ 2²⁴ —
/// far beyond any dataset here).  Drivers attach it next to the
/// materialized batch tensors whenever the dataset was registered with
/// the executor via `host_dataset`, so an index-mode cluster transport
/// can ship O(batch) indices instead of pixels while every other
/// backend ignores the extra entry (DESIGN.md §18).
pub fn source_io(dataset_id: u32, idx: &[usize]) -> Tensor {
    let mut v = Vec::with_capacity(idx.len() + 1);
    v.push(dataset_id as f32);
    v.extend(idx.iter().map(|&i| i as f32));
    Tensor::from_f32(&[idx.len() + 1], v)
}

impl<'a> EpochBatcher<'a> {
    pub fn new(ds: &'a Dataset, batch: usize, seed: u64) -> EpochBatcher<'a> {
        assert!(batch <= ds.len(), "batch {} > dataset {}", batch, ds.len());
        let mut rng = Rng::new(seed ^ 0xBA7C4);
        let mut order: Vec<usize> = (0..ds.len()).collect();
        rng.shuffle(&mut order);
        EpochBatcher { ds, batch, order, pos: 0, rng, epoch: 0 }
    }

    /// Full batches delivered per `ds.len()` draws, on average: the
    /// floor when `batch` divides the dataset exactly; with a carried
    /// tail the boundary batch draws from two adjacent permutations, so
    /// long-run delivery is `len/batch` batches per epoch exactly.
    pub fn batches_per_epoch(&self) -> usize {
        self.ds.len() / self.batch
    }

    /// Draw the next `batch` sample indices from the permutation stream;
    /// reshuffles (and advances `epoch`) at each permutation boundary.
    pub fn next_indices(&mut self) -> Vec<usize> {
        let n = self.ds.len();
        let mut idx = Vec::with_capacity(self.batch);
        while idx.len() < self.batch {
            if self.pos == n {
                self.rng.shuffle(&mut self.order);
                self.pos = 0;
                self.epoch += 1;
                // Repair: keep indices already drawn into this partial
                // batch out of the slots that will complete it, so no
                // batch ever contains a duplicate.  Feasible because
                // batch ≤ n: there are ≥ `need` candidates outside the
                // partial batch.
                let need = self.batch - idx.len();
                let mut swap_from = need;
                for i in 0..need {
                    if idx.contains(&self.order[i]) {
                        while swap_from < n && idx.contains(&self.order[swap_from]) {
                            swap_from += 1;
                        }
                        debug_assert!(swap_from < n, "no duplicate-free slot");
                        self.order.swap(i, swap_from);
                        swap_from += 1;
                    }
                }
            }
            idx.push(self.order[self.pos]);
            self.pos += 1;
        }
        idx
    }

    /// Next (x, y) batch; reshuffles on epoch boundary.
    pub fn next_batch(&mut self) -> (Tensor, Tensor) {
        let idx = self.next_indices();
        self.ds.gather(&idx)
    }

    /// Snapshot the stream position for a checkpoint sidecar.
    pub fn cursor(&self) -> BatcherCursor {
        BatcherCursor {
            order: self.order.clone(),
            pos: self.pos,
            epoch: self.epoch,
            rng: self.rng.state(),
        }
    }

    /// Restore a [`BatcherCursor`] snapshot taken on a batcher over the
    /// same dataset; subsequent draws continue the stream bit-exactly.
    pub fn restore(&mut self, c: &BatcherCursor) -> Result<()> {
        ensure!(
            c.order.len() == self.ds.len() && c.pos <= c.order.len(),
            "batcher cursor does not match the dataset (order {} vs {}, pos {})",
            c.order.len(),
            self.ds.len(),
            c.pos
        );
        let mut sorted = c.order.clone();
        sorted.sort_unstable();
        ensure!(
            sorted.iter().enumerate().all(|(i, &v)| i == v),
            "batcher cursor order is not a permutation"
        );
        self.order.clone_from(&c.order);
        self.pos = c.pos;
        self.epoch = c.epoch;
        self.rng = Rng::from_state(c.rng);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};

    #[test]
    fn batches_have_fixed_shape_and_cover_dataset() {
        let (ds, _) = generate(&SynthSpec::tiny(2));
        let mut b = EpochBatcher::new(&ds, 16, 0);
        let mut seen = vec![0usize; ds.classes];
        for _ in 0..b.batches_per_epoch() {
            let (x, y) = b.next_batch();
            assert_eq!(x.shape()[0], 16);
            for &l in y.as_i32().unwrap() {
                seen[l as usize] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c > 0));
    }

    #[test]
    fn epoch_advances_and_reshuffles() {
        let (ds, _) = generate(&SynthSpec::tiny(2));
        let mut b = EpochBatcher::new(&ds, ds.len(), 0);
        let (x1, _) = b.next_batch();
        let (x2, _) = b.next_batch();
        assert_eq!(b.epoch, 1);
        // same multiset of samples, different order with high probability
        assert_ne!(x1.as_f32().unwrap()[..64], x2.as_f32().unwrap()[..64]);
    }

    #[test]
    fn deterministic_given_seed() {
        let (ds, _) = generate(&SynthSpec::tiny(2));
        let (a, _) = EpochBatcher::new(&ds, 8, 3).next_batch();
        let (b, _) = EpochBatcher::new(&ds, 8, 3).next_batch();
        assert_eq!(a, b);
    }

    #[test]
    fn every_sample_exactly_once_per_len_draws() {
        // Regression for the tail-carry bug: with batch ∤ len, each
        // window of len consecutive draws must be a permutation.
        let (ds, _) = generate(&SynthSpec::tiny(4));
        let n = ds.len();
        for batch in [48usize, 100, 7] {
            let mut b = EpochBatcher::new(&ds, batch, 9);
            let mut draws = Vec::new();
            while draws.len() < 3 * n {
                draws.extend(b.next_indices());
            }
            for (epoch, window) in draws.chunks_exact(n).take(3).enumerate() {
                let mut counts = vec![0usize; n];
                for &i in window {
                    counts[i] += 1;
                }
                assert!(
                    counts.iter().all(|&c| c == 1),
                    "batch {batch}, epoch {epoch}: uneven coverage"
                );
            }
        }
    }

    #[test]
    fn sharded_epoch_draws_every_example_exactly_once_with_disjoint_shards() {
        // The executor's batch-sharding contract: splitting each drawn
        // batch by a fixed ShardPlan yields (a) pairwise-disjoint shard
        // index sets inside every batch and (b) exactly-once coverage of
        // the dataset per epoch by the union of the shards — at every
        // shard count, because the draw stream is shard-independent.
        use crate::exec::{ShardPlan, ShardSpec};
        let (ds, _) = generate(&SynthSpec::tiny(8));
        let n = ds.len();
        for batch in [16usize, 48, 100] {
            for shards in [1usize, 2, 4] {
                let plan = ShardPlan::new(batch, ShardSpec::new(shards, 4));
                let mut b = EpochBatcher::new(&ds, batch, 77);
                let mut counts = vec![0usize; n];
                let mut drawn = 0usize;
                while drawn < n {
                    let idx = b.next_indices();
                    drawn += idx.len();
                    let mut seen_in_batch = std::collections::HashSet::new();
                    for s in 0..plan.shards {
                        for &i in &idx[plan.shard_examples(s)] {
                            assert!(
                                seen_in_batch.insert(i),
                                "shards overlap within a batch (batch {batch}, shards {shards})"
                            );
                            counts[i] += 1;
                        }
                    }
                    assert_eq!(seen_in_batch.len(), batch, "shards must cover the whole batch");
                }
                if n % batch == 0 {
                    assert!(
                        counts.iter().all(|&c| c == 1),
                        "epoch coverage broken at batch {batch}, shards {shards}"
                    );
                }
            }
        }
    }

    #[test]
    fn cursor_restore_continues_the_draw_stream_bit_exactly() {
        let (ds, _) = generate(&SynthSpec::tiny(4));
        // batch ∤ len so the continuation crosses an epoch boundary and
        // exercises the reshuffle + duplicate-repair path post-restore.
        let mut a = EpochBatcher::new(&ds, 48, 21);
        for _ in 0..7 {
            a.next_indices();
        }
        let cur = a.cursor();
        let mut b = EpochBatcher::new(&ds, 48, 999); // wrong seed on purpose
        b.restore(&cur).unwrap();
        assert_eq!(b.epoch, a.epoch);
        for _ in 0..30 {
            assert_eq!(a.next_indices(), b.next_indices());
        }
    }

    #[test]
    fn cursor_restore_rejects_mismatched_snapshots() {
        let (ds, _) = generate(&SynthSpec::tiny(2));
        let mut b = EpochBatcher::new(&ds, 16, 0);
        let mut cur = b.cursor();
        cur.order.pop();
        assert!(b.restore(&cur).is_err(), "wrong order length must be rejected");
        let mut cur = b.cursor();
        cur.order[0] = cur.order[1];
        assert!(b.restore(&cur).is_err(), "non-permutation order must be rejected");
    }

    #[test]
    fn source_io_encodes_id_then_indices_exactly() {
        let t = source_io(3, &[0, 7, 1 << 24]);
        assert_eq!(t.shape(), &[4]);
        let v = t.as_f32().unwrap();
        assert_eq!(v, &[3.0, 0.0, 7.0, 16_777_216.0]);
        assert_eq!(v[3] as u32, 1 << 24); // round-trips exactly
    }

    #[test]
    fn no_duplicates_within_a_batch() {
        let (ds, _) = generate(&SynthSpec::tiny(6));
        // 512 % 48 != 0 → plenty of boundary-spanning batches.
        let mut b = EpochBatcher::new(&ds, 48, 1);
        for _ in 0..40 {
            let idx = b.next_indices();
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), idx.len(), "duplicate inside one batch: {idx:?}");
        }
    }
}
