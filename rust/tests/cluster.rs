//! Coordinator/worker cluster transport tests (DESIGN.md §18): a
//! same-seed search must be bit-identical whether replicas run as
//! in-process pool threads or as workers behind [`ClusterTransport`] —
//! at any worker count, in both wire modes (index-only phases against
//! worker-resident datasets, and inline payload), under skewed
//! throughput-aware chunk scheduling, and through injected worker
//! deaths mid-epoch, mid-rendezvous, and mid-pipelined-sync (chunks
//! requeued onto the survivors), including an elastic rejoin that binds
//! a pre-seeded dataset by fingerprint.
//!
//! Workers here are real `run_worker` main loops on localhost TCP, run
//! on std threads instead of child processes so the tests need no
//! target binary and fault injection stays deterministic.

use std::time::Duration;

use ebs::coordinator::{
    run_fp_train, run_retrain, run_search, FlopsModel, RunLogger, SearchCfg, SearchResult,
    Selection, TrainCfg,
};
use ebs::data::synth::{generate, Dataset, SynthSpec};
use ebs::exec::wire::OP_DATASET_LOAD;
use ebs::exec::{
    run_worker, run_worker_seeded, ClusterTransport, ShardSpec, StepExecutor, WireMode, WorkerFault,
};

mod common;
use common::open_engine;

const MODEL: &str = "resnet8_tiny";

/// The seeded tiny task every run in this file shares: `(full_train,
/// test)` for the training drivers, plus the deterministic search
/// split.  One source of truth so cluster workers can be pre-seeded
/// with byte-identical copies (fingerprint binding).
fn search_data() -> (Dataset, Dataset, Dataset, Dataset) {
    let mut spec_data = SynthSpec::tiny(13);
    spec_data.n_train = 192;
    spec_data.n_test = 64;
    let (train, test) = generate(&spec_data);
    let (s_train, s_val) = train.split(0.5, 5);
    (train, test, s_train, s_val)
}

/// Fixed-seed Algorithm 1 on seeded tiny data through whatever
/// transport `exec` carries.  Every run in this file shares the same
/// data, seeds, and canonical `chunks = 4`, so results are comparable
/// bit-for-bit across transports, worker counts, and wire modes.
fn search_with(exec: &mut StepExecutor) -> SearchResult {
    let flops = FlopsModel::from_manifest(&exec.manifest).unwrap();
    let target = flops.uniform_mflops(3);
    let (_, _, s_train, s_val) = search_data();
    let mut logger = RunLogger::ephemeral();
    let cfg = SearchCfg {
        steps: 10,
        eval_every: 6,
        log_every: 1000,
        lambda: 1.0,
        seed: 42,
        ..SearchCfg::defaults(target, 0)
    };
    let mut state = exec.init_state(9).unwrap();
    run_search(exec, &mut state, &s_train, &s_val, &cfg, &mut logger).unwrap()
}

/// The in-process reference: the scoped-thread pool at 2 shards over
/// the same canonical 4 chunks the cluster runs use.
fn in_process_search() -> SearchResult {
    let mut exec = StepExecutor::new(open_engine(MODEL), ShardSpec::new(2, 4));
    search_with(&mut exec)
}

/// One cluster run's shape: the worker fleet (one entry per worker,
/// faults included), the wire mode, an optional pre-seeded EWMA skew
/// (uneven scheduler runs from step one), and an optional elastic
/// rejoiner that dials in pre-seeded with the datasets.
struct Fleet<'a> {
    faults: &'a [WorkerFault],
    wire: WireMode,
    ewma_ms: Option<&'a [f64]>,
    rejoin_seeded: bool,
}

impl Default for Fleet<'_> {
    fn default() -> Self {
        Fleet { faults: &[], wire: WireMode::Index, ewma_ms: None, rejoin_seeded: false }
    }
}

/// Run the search behind a coordinator with one worker per fault spec
/// (`WorkerFault::default()` = a healthy worker).  Workers dial in one
/// at a time so fault specs target a known worker index.
fn cluster_search(fleet: Fleet) -> SearchResult {
    let mut exec = StepExecutor::new(open_engine(MODEL), ShardSpec::new(1, 4));
    let mut ct = ClusterTransport::listen("127.0.0.1:0", MODEL).unwrap();
    ct.set_wire_mode(fleet.wire);
    let addr = ct.local_addr().unwrap().to_string();
    let mut workers = Vec::new();
    for (i, &fault) in fleet.faults.iter().enumerate() {
        let dial = addr.clone();
        workers.push(std::thread::spawn(move || run_worker(&dial, 1, fault)));
        ct.wait_for_workers(i + 1, Duration::from_secs(30)).unwrap();
    }
    if let Some(ms) = fleet.ewma_ms {
        ct.preset_ewma(ms);
    }
    if fleet.rejoin_seeded {
        // An extra worker dials in already holding byte-identical
        // dataset copies: the coordinator accepts it at the next phase
        // boundary and its handshake binds the hosted ids to the
        // advertised fingerprints instead of re-shipping content.
        let (_, _, s_train, s_val) = search_data();
        let dial = addr.clone();
        workers.push(std::thread::spawn(move || {
            run_worker_seeded(&dial, 1, WorkerFault::default(), vec![s_train, s_val])
        }));
    }
    exec.set_transport(Box::new(ct)).unwrap();
    let res = search_with(&mut exec);
    // Dropping the executor drops the transport, whose Drop sends
    // Shutdown to every live worker; faulted workers exited earlier.
    drop(exec);
    for w in workers {
        w.join().expect("worker thread panicked").expect("worker main loop errored");
    }
    res
}

#[test]
fn cluster_search_is_bit_identical_to_in_process() {
    let reference = in_process_search();
    for n in [1usize, 2, 3] {
        let faults = vec![WorkerFault::default(); n];
        let got = cluster_search(Fleet { faults: &faults, ..Fleet::default() });
        assert_eq!(
            reference, got,
            "{n}-worker index-mode cluster must match the in-process pool bit-for-bit"
        );
    }
}

#[test]
fn payload_wire_mode_is_bit_identical_too() {
    let reference = in_process_search();
    let faults = [WorkerFault::default(), WorkerFault::default()];
    let got = cluster_search(Fleet {
        faults: &faults,
        wire: WireMode::Payload,
        ..Fleet::default()
    });
    assert_eq!(reference, got, "payload-mode cluster must match the in-process pool bit-for-bit");
}

/// A 9:1 pre-seeded latency skew makes the throughput-aware scheduler
/// hand worker 0 most of the grid from the first step (contiguous
/// whole-chunk runs, uneven sizes).  The combine order is the global
/// chunk order regardless, so the bits cannot move.
#[test]
fn uneven_scheduler_chunk_runs_stay_bit_identical() {
    let reference = in_process_search();
    let faults = [WorkerFault::default(), WorkerFault::default()];
    let got = cluster_search(Fleet {
        faults: &faults,
        ewma_ms: Some(&[1.0, 9.0]),
        ..Fleet::default()
    });
    assert_eq!(reference, got, "skewed chunk runs must not change the bits");
}

/// Each search step dispatches the weight phase then the arch phase, so
/// phase index 4 is the weight phase of step 2: worker 1 receives the
/// dispatch and vanishes without a reply.  The coordinator must abort
/// the attempt, requeue worker 1's chunks onto the survivor, and finish
/// with the exact bits of an uninterrupted run.
#[test]
fn worker_killed_mid_epoch_is_requeued_bit_identically() {
    let reference = in_process_search();
    let faults = [
        WorkerFault::default(),
        WorkerFault { phase: Some(4), ..WorkerFault::default() },
    ];
    let faulted = cluster_search(Fleet { faults: &faults, ..Fleet::default() });
    assert_eq!(
        reference, faulted,
        "search with a worker killed mid-epoch must stay bit-identical"
    );
}

/// Phase index 5 is the arch phase of step 2 — a train phase, so with
/// two live workers its sync-BN moments rendezvous through the
/// coordinator hub.  Worker 1 ships its first moment partial of that
/// phase and then dies, leaving worker 0 blocked inside the rendezvous:
/// the poisoned hub must unblock it, the abort must drain cleanly, and
/// the requeued retry must reproduce the uninterrupted bits.
#[test]
fn worker_killed_mid_rendezvous_is_requeued_bit_identically() {
    let reference = in_process_search();
    let faults = [
        WorkerFault::default(),
        WorkerFault { moment: Some(5), ..WorkerFault::default() },
    ];
    let faulted = cluster_search(Fleet { faults: &faults, ..Fleet::default() });
    assert_eq!(
        reference, faulted,
        "search with a worker killed mid-rendezvous must stay bit-identical"
    );
}

/// Worker 1 dies on the 4th pipelined StateSync *before acking it* —
/// the coordinator has already fused [sync][phase] onto the socket, so
/// the ack gate must catch the silence, abort the attempt, and re-plan
/// on the survivor without ever starting a phase on stale weights.
#[test]
fn worker_killed_mid_pipelined_sync_is_requeued_bit_identically() {
    let reference = in_process_search();
    let faults = [
        WorkerFault::default(),
        WorkerFault { sync: Some(4), ..WorkerFault::default() },
    ];
    let faulted = cluster_search(Fleet { faults: &faults, ..Fleet::default() });
    assert_eq!(
        reference, faulted,
        "search with a worker killed mid-pipelined-sync must stay bit-identical"
    );
}

/// Elastic rejoin: worker 1 dies early (sync fault at phase 2), while a
/// replacement that already holds byte-identical dataset copies dials
/// in.  The coordinator accepts it at a phase boundary, its Hello
/// fingerprints bind the hosted ids without re-shipping pixels, and the
/// final bits match the uninterrupted run at any join timing.
#[test]
fn elastic_rejoin_with_seeded_datasets_stays_bit_identical() {
    let reference = in_process_search();
    let faults = [
        WorkerFault::default(),
        WorkerFault { sync: Some(2), ..WorkerFault::default() },
    ];
    let got = cluster_search(Fleet { faults: &faults, rejoin_seeded: true, ..Fleet::default() });
    assert_eq!(reference, got, "elastic rejoin must not change the bits");
}

/// The tentpole's payoff, asserted on the exact metric the cluster
/// bench reports: per epoch of steady-state steps, index mode must move
/// ≥10× fewer phase-data-path bytes (PhaseStart + DatasetLoad during
/// the timed window) than payload mode.  The one-time DatasetLoad ship
/// happens at hosting time — before the window — and only in index
/// mode.
#[test]
fn index_mode_cuts_phase_wire_bytes_10x() {
    let bytes_per_epoch = |wire: WireMode| -> (f64, u64) {
        let mut exec = StepExecutor::new(open_engine(MODEL), ShardSpec::new(1, 4));
        let mut ct = ClusterTransport::listen("127.0.0.1:0", MODEL).unwrap();
        ct.set_wire_mode(wire);
        let addr = ct.local_addr().unwrap().to_string();
        let mut workers = Vec::new();
        for _ in 0..2 {
            let dial = addr.clone();
            workers
                .push(std::thread::spawn(move || run_worker(&dial, 1, WorkerFault::default())));
        }
        ct.wait_for_workers(2, Duration::from_secs(30)).unwrap();
        exec.set_transport(Box::new(ct)).unwrap();
        let (_, _, s_train, s_val) = search_data();
        let mut state = exec.init_state(9).unwrap();
        let cost = ebs::baselines::dnas::run_dataset_search_steps(
            &mut exec, &mut state, &s_train, &s_val, 5, 7,
        )
        .unwrap();
        let t = exec.wire_stats().expect("cluster transport must report wire totals");
        let ds_bytes = t.per_op[OP_DATASET_LOAD as usize].sent_bytes;
        drop(exec);
        for w in workers {
            w.join().expect("worker thread panicked").expect("worker main loop errored");
        }
        (cost.wire_bytes_per_epoch.expect("cluster run must measure wire bytes"), ds_bytes)
    };
    let (idx, idx_ds) = bytes_per_epoch(WireMode::Index);
    let (pay, pay_ds) = bytes_per_epoch(WireMode::Payload);
    assert!(idx_ds > 0, "index mode must ship the datasets once");
    assert_eq!(pay_ds, 0, "payload mode must never ship datasets");
    assert!(
        pay >= 10.0 * idx,
        "index-only phases must cut phase-data bytes/epoch ≥10×: payload {pay} vs index {idx}"
    );
}

/// FP pretrain and quantized retrain ride the same sharded data path as
/// the search: both must be bit-identical between the in-process pool
/// and a 2-worker index-mode cluster — results *and* every state leaf.
#[test]
fn cluster_pretrain_and_retrain_are_bit_identical_to_in_process() {
    let run_drivers = |exec: &mut StepExecutor| {
        let (train, test, _, _) = search_data();
        let cfg = TrainCfg {
            steps: 6,
            eval_every: 4,
            log_every: 1000,
            seed: 11,
            ..TrainCfg::defaults(0)
        };
        let mut logger = RunLogger::ephemeral();
        let mut fp_state = exec.init_state(9).unwrap();
        let fp = run_fp_train(exec, &mut fp_state, &train, &test, &cfg, &mut logger).unwrap();
        let sel = Selection::from_state(&fp_state, &exec.manifest).unwrap();
        let mut rt_state = exec.init_state(9).unwrap();
        rt_state.transfer_from(&fp_state, "state/params/");
        let rt = run_retrain(
            exec, &mut rt_state, &sel, &train, &test, &cfg, None, &mut logger,
        )
        .unwrap();
        (fp, fp_state, rt, rt_state)
    };
    let mut ref_exec = StepExecutor::new(open_engine(MODEL), ShardSpec::new(2, 4));
    let (ref_fp, ref_fp_state, ref_rt, ref_rt_state) = run_drivers(&mut ref_exec);

    let mut exec = StepExecutor::new(open_engine(MODEL), ShardSpec::new(1, 4));
    let mut ct = ClusterTransport::listen("127.0.0.1:0", MODEL).unwrap();
    let addr = ct.local_addr().unwrap().to_string();
    let mut workers = Vec::new();
    for _ in 0..2 {
        let dial = addr.clone();
        workers.push(std::thread::spawn(move || run_worker(&dial, 1, WorkerFault::default())));
    }
    ct.wait_for_workers(2, Duration::from_secs(30)).unwrap();
    exec.set_transport(Box::new(ct)).unwrap();
    let (fp, fp_state, rt, rt_state) = run_drivers(&mut exec);
    let spec: Vec<String> = exec.manifest.state_spec.iter().map(|l| l.path.clone()).collect();
    drop(exec);
    for w in workers {
        w.join().expect("worker thread panicked").expect("worker main loop errored");
    }

    assert_eq!(ref_fp.best_test_acc.to_bits(), fp.best_test_acc.to_bits());
    assert_eq!(ref_fp.final_train_loss.to_bits(), fp.final_train_loss.to_bits());
    assert_eq!(ref_rt.best_test_acc.to_bits(), rt.best_test_acc.to_bits());
    assert_eq!(ref_rt.final_train_loss.to_bits(), rt.final_train_loss.to_bits());
    for path in &spec {
        assert_eq!(
            ref_fp_state.get(path).unwrap(),
            fp_state.get(path).unwrap(),
            "fp state leaf {path} diverged"
        );
        assert_eq!(
            ref_rt_state.get(path).unwrap(),
            rt_state.get(path).unwrap(),
            "retrain state leaf {path} diverged"
        );
    }
}
