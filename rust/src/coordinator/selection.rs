//! Bitwidth selection (paper Eq. 4): the discrete per-layer (M, K)
//! assignment extracted from learned strengths, plus the one-hot
//! coefficient encoding fed back into the retrain/eval/infer graphs.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::{Manifest, StateVec, Tensor};
use crate::util::json::{parse, Json};
use crate::util::Rng;

use super::flops::FlopsModel;

/// Per-layer bitwidths for weights and activations (manifest qconv order).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Selection {
    pub w_bits: Vec<u32>,
    pub x_bits: Vec<u32>,
}

impl Selection {
    /// Uniform-precision selection (baseline rows of Tables 1/2).
    pub fn uniform(w: u32, x: u32, layers: usize) -> Selection {
        Selection { w_bits: vec![w; layers], x_bits: vec![x; layers] }
    }

    /// Eq. 4: argmax over the learned strengths in a search state.
    pub fn from_state(state: &StateVec, manifest: &Manifest) -> Result<Selection> {
        let argmax_bits = |prefix: &str| -> Result<Vec<u32>> {
            manifest
                .qconv_layers
                .iter()
                .map(|name| {
                    let t = state.get(&format!("state/arch/{prefix}/{name}"))?;
                    let v = t.as_f32()?;
                    let idx = v
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(i, _)| i)
                        .unwrap();
                    Ok(manifest.bits[idx])
                })
                .collect()
        };
        Ok(Selection { w_bits: argmax_bits("r")?, x_bits: argmax_bits("s")? })
    }

    /// Random-search baseline: sample uniformly until the exact cost
    /// lands within ±`tol` (relative) of `target_mflops` (paper §5.1
    /// keeps only QNNs whose FLOPs are in the target range).
    pub fn random_within(
        rng: &mut Rng,
        flops: &FlopsModel,
        target_mflops: f64,
        tol: f64,
        max_tries: usize,
    ) -> Result<Selection> {
        let l = flops.num_layers();
        for _ in 0..max_tries {
            let w: Vec<u32> = (0..l).map(|_| flops.bits[rng.below(flops.bits.len())]).collect();
            let x: Vec<u32> = (0..l).map(|_| flops.bits[rng.below(flops.bits.len())]).collect();
            let sel = Selection { w_bits: w, x_bits: x };
            let mf = flops.exact_mflops(&sel.w_bits, &sel.x_bits);
            if (mf - target_mflops).abs() / target_mflops <= tol {
                return Ok(sel);
            }
        }
        bail!("no random selection hit {target_mflops:.2} MFLOPs (±{tol:.0?}) in {max_tries} tries")
    }

    /// One-hot (L, N) coefficient tensors for the train/eval/infer graphs.
    pub fn to_onehot(&self, manifest: &Manifest) -> Result<(Tensor, Tensor)> {
        let n = manifest.bits.len();
        let l = self.w_bits.len();
        if l != manifest.num_qconvs() {
            bail!("selection has {l} layers, model has {}", manifest.num_qconvs());
        }
        let encode = |bits: &[u32]| -> Result<Tensor> {
            let mut data = vec![0f32; l * n];
            for (i, &b) in bits.iter().enumerate() {
                let idx = manifest
                    .bits
                    .iter()
                    .position(|&c| c == b)
                    .with_context(|| format!("bitwidth {b} not a candidate"))?;
                data[i * n + idx] = 1.0;
            }
            Ok(Tensor::from_f32(&[l, n], data))
        };
        Ok((encode(&self.w_bits)?, encode(&self.x_bits)?))
    }

    /// Average bitwidths (Fig. 7 commentary: weights skew lower than acts).
    pub fn mean_bits(&self) -> (f64, f64) {
        let mw = self.w_bits.iter().map(|&b| b as f64).sum::<f64>() / self.w_bits.len() as f64;
        let mx = self.x_bits.iter().map(|&b| b as f64).sum::<f64>() / self.x_bits.len() as f64;
        (mw, mx)
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            (
                "w_bits".into(),
                Json::Arr(self.w_bits.iter().map(|&b| Json::Num(b as f64)).collect()),
            ),
            (
                "x_bits".into(),
                Json::Arr(self.x_bits.iter().map(|&b| Json::Num(b as f64)).collect()),
            ),
        ])
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Selection> {
        let j = parse(&std::fs::read_to_string(path)?)
            .with_context(|| format!("parsing selection {}", path.display()))?;
        let bits = |key: &str| -> Result<Vec<u32>> {
            j.req(key)?
                .as_arr()?
                .iter()
                .map(|v| Ok(v.as_usize()? as u32))
                .collect()
        };
        Ok(Selection { w_bits: bits("w_bits")?, x_bits: bits("x_bits")? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::flops::FlopsModel;

    fn toy_flops() -> FlopsModel {
        FlopsModel {
            fp_macs: 100_000,
            qconv_macs: (0..6).map(|i| (format!("l{i}"), 1_000_000u64)).collect(),
            bits: vec![1, 2, 3, 4, 5],
            fp32_mflops: 6.1,
        }
    }

    #[test]
    fn random_search_respects_target_window() {
        let f = toy_flops();
        let target = f.uniform_mflops(3);
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let s = Selection::random_within(&mut rng, &f, target, 0.1, 10_000).unwrap();
            let mf = f.exact_mflops(&s.w_bits, &s.x_bits);
            assert!((mf - target).abs() / target <= 0.1);
        }
    }

    #[test]
    fn mean_bits() {
        let s = Selection { w_bits: vec![1, 2, 3], x_bits: vec![4, 4, 4] };
        let (mw, mx) = s.mean_bits();
        assert!((mw - 2.0).abs() < 1e-9);
        assert!((mx - 4.0).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip() {
        let s = Selection { w_bits: vec![1, 5], x_bits: vec![2, 3] };
        let tmp = std::env::temp_dir().join("ebs_sel_test.json");
        s.save(&tmp).unwrap();
        assert_eq!(Selection::load(&tmp).unwrap(), s);
        std::fs::remove_file(&tmp).ok();
    }
}
