//! One deployed mixed precision convolution layer (paper §4.3):
//! im2col → activation quantization → bitplane packing → AND/popcount
//! GEMM → powers-of-two recombination → affine decode → folded BN →
//! optional ReLU.
//!
//! Weights are packed once at build time (B_w is the *stored* format —
//! the paper's memory argument: `s·co·M` bits ≈ the quantized weights
//! themselves, plus M·K powers-of-two, §4.3 Complexities).

use anyhow::Result;

use crate::quant::{quantize_acts, quantize_weights};

use super::bitplane::{pack_cols, pack_rows, BitMatrix};
use super::gemm;
use super::im2col::im2col;

/// Execution strategy — the paper-literal two-stage path keeps P
/// materialized; the fused path folds Eq. 14 into the popcount loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BdMode {
    #[default]
    Fused,
    TwoStage,
}

/// A ready-to-run BD conv layer.
pub struct BdConvLayer {
    pub name: String,
    pub ci: usize,
    pub co: usize,
    pub k: usize,
    pub stride: usize,
    pub m_bits: u32,
    pub k_bits: u32,
    pub alpha: f32,
    /// Packed weight bitplanes: (co·M) × s.
    pub bw: BitMatrix,
    w_scale: f32,
    w_zero: f32,
    /// Folded per-channel output transform (BN eval): y = scale·o + bias.
    pub out_scale: Vec<f32>,
    pub out_bias: Vec<f32>,
    pub relu: bool,
    pub mode: BdMode,
}

impl BdConvLayer {
    /// Build from float weights (HWIO flattened), BN eval statistics and
    /// the layer's searched bitwidths.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: &str,
        weights: &[f32],
        ci: usize,
        co: usize,
        k: usize,
        stride: usize,
        m_bits: u32,
        k_bits: u32,
        alpha: f32,
        bn: Option<(&[f32], &[f32], &[f32], &[f32], f32)>, // gamma, beta, mean, var, eps
        relu: bool,
    ) -> Result<BdConvLayer> {
        let s = k * k * ci;
        anyhow::ensure!(weights.len() == s * co, "weight size mismatch for {name}");
        let q = quantize_weights(weights, m_bits);
        // Repack codes from HWIO (s-major over rows of W[s][co]) to the
        // BD layout W[co][s]: row per output channel.
        let mut codes_cs = vec![0u8; co * s];
        for si in 0..s {
            for c in 0..co {
                codes_cs[c * s + si] = q.codes[si * co + c];
            }
        }
        let bw = pack_rows(&codes_cs, co, s, m_bits);
        let (mut out_scale, mut out_bias) = (vec![1f32; co], vec![0f32; co]);
        if let Some((gamma, beta, mean, var, eps)) = bn {
            for c in 0..co {
                let g = gamma[c] / (var[c] + eps).sqrt();
                out_scale[c] = g;
                out_bias[c] = beta[c] - g * mean[c];
            }
        }
        Ok(BdConvLayer {
            name: name.to_string(),
            ci,
            co,
            k,
            stride,
            m_bits,
            k_bits,
            alpha,
            bw,
            w_scale: q.scale,
            w_zero: q.zero,
            out_scale,
            out_bias,
            relu,
            mode: BdMode::Fused,
        })
    }

    /// Forward one image (h×w×ci NHWC) → (oh·ow×co NHWC, oh, ow).
    pub fn forward(&self, x: &[f32], h: usize, w: usize) -> (Vec<f32>, usize, usize) {
        let p = im2col(x, h, w, self.ci, self.k, self.stride);
        // Activation quantization (Eq. 1b) on the patch matrix.
        let mut codes = vec![0u8; p.data.len()];
        let x_scale = quantize_acts(&p.data, self.alpha, self.k_bits, &mut codes);
        let (bx, col_sums) = pack_cols(&codes, p.s, p.n, self.k_bits);

        // Integer product via Binary Decomposition.
        let prod = match self.mode {
            BdMode::Fused => gemm::fused(&self.bw, &bx, self.co, p.n, self.m_bits, self.k_bits),
            BdMode::TwoStage => {
                let pm = gemm::binary_gemm_p(&self.bw, &bx);
                gemm::recombine(&pm, self.co, p.n, self.m_bits, self.k_bits)
            }
        };

        // Affine decode + folded BN + ReLU, emitted NHWC.
        let mut out = vec![0f32; p.n * self.co];
        let sw_sx = self.w_scale * x_scale;
        let zw_sx = self.w_zero * x_scale;
        for i in 0..self.co {
            let (a, b) = (self.out_scale[i], self.out_bias[i]);
            for j in 0..p.n {
                let real = sw_sx * prod[i * p.n + j] as f32 + zw_sx * col_sums[j] as f32;
                let mut v = a * real + b;
                if self.relu && v < 0.0 {
                    v = 0.0;
                }
                out[j * self.co + i] = v;
            }
        }
        (out, p.oh, p.ow)
    }

    /// Model size of the packed weights in bytes (Table 4 discussion).
    pub fn packed_bytes(&self) -> usize {
        self.bw.size_bytes()
    }

    /// Eq. 2 operation count: AND ops for one forward at (oh·ow) = n.
    pub fn and_ops(&self, n: usize) -> u64 {
        (self.k * self.k * self.ci) as u64 * n as u64 * self.co as u64
            * self.m_bits as u64 * self.k_bits as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bd::reference::conv2d_fakequant;
    use crate::util::Rng;

    /// The BD layer (integer path) must match the fake-quantized float
    /// conv (training-graph semantics) to float tolerance.
    #[test]
    fn bd_layer_equals_fakequant_reference() {
        let mut rng = Rng::new(0xC0FFEE);
        for &(ci, co, k, stride, mb, kb) in &[
            (3usize, 8usize, 3usize, 1usize, 2u32, 3u32),
            (8, 16, 3, 2, 1, 1),
            (16, 8, 1, 1, 4, 2),
            (5, 7, 3, 1, 5, 5),
        ] {
            let (h, w) = (9, 9);
            let x: Vec<f32> = (0..h * w * ci).map(|_| rng.normal().abs()).collect();
            let wts: Vec<f32> = (0..k * k * ci * co).map(|_| 0.5 * rng.normal()).collect();
            let alpha = 2.5f32;

            let layer = BdConvLayer::new(
                "t", &wts, ci, co, k, stride, mb, kb, alpha, None, false,
            )
            .unwrap();
            let (got, oh, ow) = layer.forward(&x, h, w);
            let (want, oh2, ow2) =
                conv2d_fakequant(&x, h, w, ci, &wts, co, k, stride, mb, kb, alpha);
            assert_eq!((oh, ow), (oh2, ow2));
            let max_err = got
                .iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(
                max_err < 2e-3,
                "max err {max_err} at ci={ci} co={co} k={k} s={stride} M={mb} K={kb}"
            );
        }
    }

    #[test]
    fn fused_and_two_stage_agree() {
        let mut rng = Rng::new(7);
        let (ci, co, k, h, w) = (4, 6, 3, 8, 8);
        let x: Vec<f32> = (0..h * w * ci).map(|_| rng.normal().abs()).collect();
        let wts: Vec<f32> = (0..k * k * ci * co).map(|_| rng.normal()).collect();
        let mut layer =
            BdConvLayer::new("t", &wts, ci, co, k, 1, 3, 2, 4.0, None, true).unwrap();
        let (a, _, _) = layer.forward(&x, h, w);
        layer.mode = BdMode::TwoStage;
        let (b, _, _) = layer.forward(&x, h, w);
        assert_eq!(a, b);
    }

    #[test]
    fn bn_fold_applies_scale_and_bias() {
        let wts = vec![0.5f32; 9]; // 1 in, 1 out, 3×3
        let gamma = [2.0f32];
        let beta = [1.0f32];
        let mean = [0.0f32];
        let var = [1.0f32 - 1e-5];
        let layer = BdConvLayer::new(
            "t", &wts, 1, 1, 3, 1, 3, 3, 1.0,
            Some((&gamma, &beta, &mean, &var, 1e-5)), false,
        )
        .unwrap();
        let x = vec![1f32; 25];
        let (out, _, _) = layer.forward(&x, 5, 5);
        // center pixel: conv ≈ 9 quantized values ≈ 9·(~0.43); y = 2o+1
        let (raw, _, _) = {
            let mut l2 = BdConvLayer::new("t", &wts, 1, 1, 3, 1, 3, 3, 1.0, None, false).unwrap();
            l2.mode = BdMode::Fused;
            l2.forward(&x, 5, 5)
        };
        for (y, o) in out.iter().zip(&raw) {
            assert!((y - (2.0 * o + 1.0)).abs() < 1e-5);
        }
    }
}
