//! Gateway-tier integration tests (DESIGN.md §15): multi-model
//! routing, atomic hot swap under concurrent load, artifact checksum
//! protection of the registry, and protocol v2 over TCP (model
//! addressing, `load` hot swaps, metrics, versioned frame errors).

use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use ebs::bd::artifact::{CKPT_FILE, SELECTION_FILE};
use ebs::bd::{BdNetwork, DeploymentArtifact};
use ebs::coordinator::Selection;
use ebs::serve::protocol::{self, Request, Response};
use ebs::serve::server::Server;
use ebs::serve::{no_loader, LoadedModel, ModelLoader, ServeCfg, ServeCore, ServeHandle};
use ebs::util::Rng;

fn gw_cfg(workers: usize, max_batch: usize, max_wait_us: u64, queue_depth: usize) -> ServeCfg {
    ServeCfg {
        addr: "127.0.0.1:0".into(),
        workers,
        max_batch,
        max_wait_us,
        queue_depth,
        metrics_addr: String::new(),
    }
}

/// Deterministic image pool sized for the synthetic net geometry.
fn images(n: usize, img_sz: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n * img_sz).map(|_| rng.normal().abs()).collect()
}

/// Tentpole contract: a hot swap under concurrent load loses no
/// request, and every answer is bit-identical to a direct
/// `classify_batch` on *whichever generation admitted it* — old-exact
/// or new-exact, never a blend.
#[test]
fn hot_swap_under_load_drops_nothing_and_answers_are_generation_exact() {
    let old = BdNetwork::synthetic(11);
    let new = BdNetwork::synthetic(22);
    let img_sz = old.input_hw * old.input_hw * old.input_ch;
    let n = 32;
    let xs = images(n, img_sz, 0xABCD);
    let old_direct = old.classify_batch(&xs, n);
    let new_direct = new.classify_batch(&xs, n);

    let core = ServeCore::new(gw_cfg(2, 4, 200, 1024), no_loader());
    let gen1 = core.load_model("m", "synthetic:11").unwrap();
    assert_eq!(gen1.generation, 1);
    let handle = ServeHandle::start(Arc::clone(&core));

    let xs = Arc::new(xs);
    let old_d = Arc::new(old_direct);
    let new_d = Arc::new(new_direct);
    let mut clients = Vec::new();
    for t in 0..4usize {
        let core = Arc::clone(&core);
        let (xs, old_d, new_d) = (Arc::clone(&xs), Arc::clone(&old_d), Arc::clone(&new_d));
        clients.push(std::thread::spawn(move || {
            for round in 0..25usize {
                // Burst of 4 mixed-size requests, then collect: keeps
                // the queue non-trivially occupied across the swap.
                let mut pending = Vec::new();
                for j in 0..4usize {
                    let count = 1 + (round + j) % 3;
                    let i = (t * 7 + round * 5 + j * 3) % (n - 3);
                    let req = xs[i * img_sz..(i + count) * img_sz].to_vec();
                    let rx = core.submit("m", req, count).expect("deep queue admits the burst");
                    pending.push((i, count, rx));
                }
                for (i, count, rx) in pending {
                    let got = rx.recv().expect("admitted request must be answered, not dropped");
                    let wo = &old_d[i..i + count];
                    let wn = &new_d[i..i + count];
                    assert!(
                        got == wo || got == wn,
                        "request [{i}..{}] must be old-net-exact or new-net-exact \
                         (got {got:?}, old {wo:?}, new {wn:?})",
                        i + count
                    );
                }
            }
        }));
    }

    // Swap while the clients are mid-flight.
    std::thread::sleep(Duration::from_millis(30));
    let gen2 = core.load_model("m", "synthetic:22").unwrap();
    assert!(gen2.generation > gen1.generation);
    for c in clients {
        c.join().unwrap();
    }

    // Post-swap admissions run on the new generation, bit-exactly.
    let got = handle.classify("m", xs[..2 * img_sz].to_vec(), 2).unwrap();
    assert_eq!(got, &new_d[..2], "post-swap request must be new-net-exact");

    let m = core.registry.resolve("m").unwrap();
    assert_eq!(m.stats.swaps.load(Ordering::Relaxed), 1, "the swap is recorded");
    assert_eq!(m.generation, gen2.generation);
    handle.shutdown();
    let admitted = core.stats.admitted.load(Ordering::Relaxed);
    let completed = core.stats.completed.load(Ordering::Relaxed);
    assert_eq!(admitted, completed, "zero-downtime swap: nothing dropped");
}

/// Multi-model routing: requests reach the model they name, per-model
/// telemetry attributes work to the right model, and the empty
/// "default" name is refused once it becomes ambiguous.
#[test]
fn multi_model_routing_is_exact_and_attributed() {
    let net_a = BdNetwork::synthetic(5);
    let net_b = BdNetwork::synthetic(6);
    let img_sz = net_a.input_hw * net_a.input_hw * net_a.input_ch;
    let n = 8;
    let xs = images(n, img_sz, 0x5151);
    let direct_a = net_a.classify_batch(&xs, n);
    let direct_b = net_b.classify_batch(&xs, n);

    let core = ServeCore::new(gw_cfg(2, 4, 500, 256), no_loader());
    core.registry.publish_synthetic("a", 5);
    core.registry.publish_synthetic("b", 6);
    let handle = ServeHandle::start(Arc::clone(&core));

    // Interleave the two models over the same inputs.
    for i in 0..n {
        let req = xs[i * img_sz..(i + 1) * img_sz].to_vec();
        let got_a = handle.classify("a", req.clone(), 1).unwrap();
        let got_b = handle.classify("b", req, 1).unwrap();
        assert_eq!(got_a, &direct_a[i..i + 1], "model a, image {i}");
        assert_eq!(got_b, &direct_b[i..i + 1], "model b, image {i}");
    }
    assert!(
        handle.classify("", xs[..img_sz].to_vec(), 1).is_err(),
        "empty model name is ambiguous with two residents"
    );
    let a = core.registry.resolve("a").unwrap();
    let b = core.registry.resolve("b").unwrap();
    assert_eq!(a.stats.images.load(Ordering::Relaxed), n as u64);
    assert_eq!(b.stats.images.load(Ordering::Relaxed), n as u64);
    let metrics = core.metrics_text();
    assert!(metrics.contains("ebs_serve_images_total{model=\"a\"} 8"), "{metrics}");
    assert!(metrics.contains("ebs_serve_images_total{model=\"b\"} 8"), "{metrics}");
    handle.shutdown();
}

/// A tampered artifact must be refused by the loader path *without*
/// disturbing the resident generation: the swap is all-or-nothing.
#[test]
fn checksum_mismatch_rejects_swap_and_keeps_current_generation() {
    let dir = std::env::temp_dir()
        .join(format!("ebs_gateway_tamper_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join(CKPT_FILE), b"checkpoint-bytes").unwrap();
    Selection { w_bits: vec![2, 3], x_bits: vec![4, 2] }
        .save(&dir.join(SELECTION_FILE))
        .unwrap();
    DeploymentArtifact::write(&dir, "m", "v-good").unwrap();
    // Tamper after sealing.
    std::fs::write(dir.join(CKPT_FILE), b"tampered-bytes").unwrap();

    // A loader that would happily serve if verification passed.
    let loader: ModelLoader = Arc::new(|source: &str| {
        let art = DeploymentArtifact::load(&PathBuf::from(source))?;
        Ok(LoadedModel { version: art.version, net: BdNetwork::synthetic(99) })
    });
    let core = ServeCore::new(gw_cfg(1, 4, 0, 64), loader);
    let gen1 = core.load_model("m", "synthetic:11").unwrap();

    let err = core
        .load_model("m", dir.to_str().unwrap())
        .expect_err("tampered artifact must be refused");
    let msg = format!("{err:#}");
    assert!(msg.contains("checksum mismatch"), "cause must name the check: {msg}");

    // The registry still serves the old generation.
    let current = core.registry.resolve("m").unwrap();
    assert_eq!(current.generation, gen1.generation, "failed swap must not disturb serving");
    assert_eq!(current.stats.swaps.load(Ordering::Relaxed), 0);
    std::fs::remove_dir_all(&dir).ok();
}

fn roundtrip(stream: &mut TcpStream, req: &Request) -> Response {
    use std::io::Write;
    stream.write_all(&protocol::encode_request(req)).unwrap();
    let payload = protocol::read_frame(stream).unwrap().expect("server hung up mid-request");
    protocol::decode_response(&payload).unwrap()
}

/// The full gateway over TCP: model-addressed classify, per-model
/// stats, a wire-driven hot swap, the metrics endpoint (protocol and
/// HTTP), and the v1-frame rejection contract.
#[test]
fn tcp_gateway_routes_swaps_and_reports() {
    use std::io::{Read, Write};

    let net_a = BdNetwork::synthetic(11);
    let img_sz = net_a.input_hw * net_a.input_hw * net_a.input_ch;
    let xs = images(2, img_sz, 0x7777);
    let direct_a = net_a.classify_batch(&xs, 2);
    let direct_swapped = BdNetwork::synthetic(33).classify_batch(&xs, 2);

    let mut cfg = gw_cfg(2, 8, 500, 256);
    cfg.metrics_addr = "127.0.0.1:0".into();
    let core = ServeCore::new(cfg, no_loader());
    core.registry.publish_synthetic("a", 11);
    core.registry.publish_synthetic("b", 22);
    let server = Server::bind(Arc::clone(&core)).unwrap();
    let addr = server.local_addr().unwrap();
    let maddr = server.metrics_addr().expect("metrics listener bound");
    let server_join = std::thread::spawn(move || server.run());

    let mut ctl = TcpStream::connect(addr).unwrap();

    // Model-addressed classify.
    let req = Request::Classify { id: 1, model: "a".into(), count: 2, images: xs.clone() };
    match roundtrip(&mut ctl, &req) {
        Response::Classify { id, labels } => {
            assert_eq!(id, 1);
            let want: Vec<u32> = direct_a.iter().map(|&p| p as u32).collect();
            assert_eq!(labels, want);
        }
        other => panic!("unexpected response {other:?}"),
    }
    // Unknown model → typed error, session survives.
    let ghost = Request::Classify { id: 2, model: "ghost".into(), count: 1, images: vec![0.0; img_sz] };
    match roundtrip(&mut ctl, &ghost) {
        Response::Error { id, code, msg } => {
            assert_eq!((id, code), (2, protocol::ERR_UNKNOWN_MODEL));
            assert!(msg.contains("ghost"), "cause names the model: {msg}");
        }
        other => panic!("unknown model must error, got {other:?}"),
    }
    // Per-model stats.
    match roundtrip(&mut ctl, &Request::Stats { id: 3, model: "a".into() }) {
        Response::Stats { id, json } => {
            assert_eq!(id, 3);
            assert!(json.contains("\"admitted\""), "{json}");
            assert!(json.contains("\"generation\""), "{json}");
        }
        other => panic!("unexpected stats response {other:?}"),
    }
    // Wire-driven hot swap; the ack reports the new generation.
    let load = Request::Load { id: 4, model: "a".into(), source: "synthetic:33".into() };
    let gen = match roundtrip(&mut ctl, &load) {
        Response::LoadAck { id, generation, version } => {
            assert_eq!(id, 4);
            assert_eq!(version, "synthetic:33");
            generation
        }
        other => panic!("unexpected load response {other:?}"),
    };
    assert!(gen >= 3, "swap generation must exceed both initial publishes");
    let req = Request::Classify { id: 5, model: "a".into(), count: 2, images: xs.clone() };
    match roundtrip(&mut ctl, &req) {
        Response::Classify { labels, .. } => {
            let want: Vec<u32> = direct_swapped.iter().map(|&p| p as u32).collect();
            assert_eq!(labels, want, "post-swap classify must be new-net-exact");
        }
        other => panic!("unexpected response {other:?}"),
    }
    // A load that fails (unknown source) is a typed error carrying the
    // cause, and serving continues.
    let bad = Request::Load { id: 6, model: "a".into(), source: "/no/such/artifact".into() };
    match roundtrip(&mut ctl, &bad) {
        Response::Error { id, code, msg } => {
            assert_eq!((id, code), (6, protocol::ERR_LOAD_FAILED));
            assert!(!msg.is_empty(), "load errors must carry a cause");
        }
        other => panic!("bad load must error, got {other:?}"),
    }
    // Metrics over the protocol.
    match roundtrip(&mut ctl, &Request::Metrics { id: 7 }) {
        Response::Metrics { id, text } => {
            assert_eq!(id, 7);
            assert!(text.contains("# TYPE ebs_serve_requests_total counter"), "{text}");
            assert!(text.contains(&format!("ebs_serve_generation{{model=\"a\"}} {gen}")), "{text}");
        }
        other => panic!("unexpected metrics response {other:?}"),
    }
    // Metrics over HTTP (the Prometheus scrape path).
    let mut scrape = TcpStream::connect(maddr).unwrap();
    scrape.write_all(b"GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    let mut http = String::new();
    scrape.read_to_string(&mut http).unwrap();
    assert!(http.starts_with("HTTP/1.1 200 OK"), "{http}");
    assert!(http.contains("ebs_serve_requests_total{model=\"a\""), "{http}");

    // The v1-frame rejection contract: a bare length-prefixed frame
    // gets a versioned error frame with the cause, then a close.
    let mut v1 = TcpStream::connect(addr).unwrap();
    v1.write_all(&[5, 0, 0, 0, 0x02, 1, 0, 0, 0]).unwrap();
    let payload = protocol::read_frame(&mut v1).unwrap().expect("error frame expected");
    match protocol::decode_response(&payload).unwrap() {
        Response::Error { id, code, msg } => {
            assert_eq!((id, code), (0, protocol::ERR_UNSUPPORTED_VERSION));
            assert!(msg.contains("magic"), "cause describes the header: {msg}");
        }
        other => panic!("v1 frame must be refused, got {other:?}"),
    }
    assert!(
        protocol::read_frame(&mut v1).unwrap().is_none(),
        "the session closes after an unrecoverable frame error"
    );

    match roundtrip(&mut ctl, &Request::Shutdown { id: 8 }) {
        Response::ShutdownAck { id } => assert_eq!(id, 8),
        other => panic!("unexpected shutdown response {other:?}"),
    }
    server_join.join().unwrap().unwrap();
}
