//! The per-replica shard context and the shard-local phase body
//! (DESIGN.md §18).
//!
//! One [`Replica`] is everything a shard-local forward(+backward)
//! touches: a persistent tape arena, one grad sink per owned canonical
//! chunk, and the per-chunk scalar partials the combiner reduces.
//! [`replica_phase`] is the single definition of "run my chunk range of
//! one phase" — the in-process transport runs it on pool threads, the
//! cluster worker process runs it over its wire-synced state view, and
//! the sharded eval path runs it with the backward disabled.  Keeping
//! one body is what makes the cluster bit-identical to the thread pool:
//! there is no second implementation to drift.

use anyhow::Result;

use crate::runtime::StateVec;

use super::graph::{Coeffs, ExecCtx, Grads, NativeNet, TapeArena};
use super::ops;

/// One data-parallel replica: everything a shard-local forward+backward
/// touches.  `grads[k]` is the sink of the replica's k-th local chunk;
/// the scalar vectors hold one per-chunk partial each, combined by the
/// single-threaded canonical reduction after the join.
#[derive(Default)]
pub(crate) struct Replica {
    pub(crate) arena: TapeArena,
    pub(crate) grads: Vec<Grads>,
    pub(crate) probs: Vec<f32>,
    pub(crate) teacher_probs: Vec<f32>,
    pub(crate) dlogits: Vec<f32>,
    /// Per-chunk Σ cross-entropy (f64, example-sum not mean).
    pub(crate) ce: Vec<f64>,
    /// Per-chunk Σ distillation KL (example-sum; empty without teacher).
    pub(crate) kl: Vec<f64>,
    /// Per-chunk correct-prediction counts (exact under any order).
    pub(crate) correct: Vec<f32>,
}

/// What one replica needs to know about its slice of a phase.  All
/// slices are already shard-local (`x`/`y`/`teacher` hold exactly this
/// shard's examples); the ctx carries the global chunk geometry.
pub(crate) struct PhaseArgs<'a> {
    /// Train-mode BN (batch statistics + running-stat capture) vs eval.
    pub train: bool,
    /// Run the backward and fill the per-chunk grad sinks.
    pub backward: bool,
    pub classes: usize,
    pub coeffs: Option<&'a Coeffs>,
    pub x: &'a [f32],
    pub y: &'a [i32],
    /// (teacher logits for this shard, μ) — label-refinery retrain.
    pub teacher: Option<(&'a [f32], f32)>,
}

/// Run one replica's share of a phase: forward over its shard (sync-BN
/// moments exchanged through `ctx.hub`), per-chunk scalar partials, and
/// — when `backward` — the per-chunk weight gradients.  Pure
/// shard-local compute over a read-only state; every state mutation
/// belongs to the combiner (DESIGN.md §14).
pub(crate) fn replica_phase(
    net: &NativeNet,
    rep: &mut Replica,
    state: &StateVec,
    a: &PhaseArgs<'_>,
    ctx: &ExecCtx<'_>,
) -> Result<()> {
    let sb = a.y.len();
    let classes = a.classes;
    let (mu, t_logits) = match a.teacher {
        Some((t, m)) if m > 0.0 => (m, Some(t)),
        _ => (0.0, None),
    };
    net.forward_ctx(state, a.coeffs, a.x, sb, a.train, &mut rep.arena, ctx)?;
    rep.ce.clear();
    rep.kl.clear();
    rep.correct.clear();
    for lex in ctx.local_chunks(sb) {
        let ly = &a.y[lex.clone()];
        let ll = &rep.arena.tape.logits[lex.start * classes..lex.end * classes];
        rep.ce.push(ops::cross_entropy(ll, ly, classes) as f64 * ly.len() as f64);
        rep.correct.push(ops::correct_count(ll, ly, classes));
        if let Some(t) = t_logits {
            let tl = &t[lex.start * classes..lex.end * classes];
            rep.kl.push(ops::distill_loss(ll, tl, lex.len(), classes) as f64 * lex.len() as f64);
        }
    }
    if !a.backward {
        return Ok(());
    }
    ops::softmax_rows(&rep.arena.tape.logits, sb, classes, &mut rep.probs);
    if let Some(t) = t_logits {
        ops::softmax_rows(t, sb, classes, &mut rep.teacher_probs);
    }
    // dlogits over the shard rows, scaled by 1/global-batch
    let inv_b = 1.0 / ctx.global_batch as f32;
    rep.dlogits.clear();
    rep.dlogits.resize(sb * classes, 0.0);
    for b in 0..sb {
        for c in 0..classes {
            let i = b * classes + c;
            let hard = rep.probs[i] - if a.y[b] as usize == c { 1.0 } else { 0.0 };
            let soft = if t_logits.is_some() {
                rep.probs[i] - rep.teacher_probs[i]
            } else {
                0.0
            };
            rep.dlogits[i] = ((1.0 - mu) * hard + mu * soft) * inv_b;
        }
    }
    let k = sb.div_ceil(ctx.chunk_size);
    while rep.grads.len() < k {
        rep.grads.push(Grads::default());
    }
    net.backward_ctx(state, a.coeffs, &mut rep.arena, &rep.dlogits, &mut rep.grads[..k], ctx)?;
    Ok(())
}
