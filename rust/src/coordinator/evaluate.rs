//! Full-dataset evaluation helpers (loss + top-1 accuracy).
//!
//! Artifacts are compiled for a fixed batch shape, so evaluation walks
//! the dataset in full batches and drops the tail (<1 batch); datasets
//! in `configs/` are sized as multiples of the batch so nothing is lost.

use anyhow::Result;

use crate::data::Dataset;
use crate::exec::StepExecutor;
use crate::runtime::{metric_f32, StateVec, Tensor};

use super::selection::Selection;

/// Aggregate evaluation result.
#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub loss: f64,
    pub accuracy: f64,
    pub samples: usize,
}

/// Evaluate a quantized network under `sel` over `ds`.
pub fn eval_quantized(
    exec: &mut StepExecutor,
    state: &mut StateVec,
    sel: &Selection,
    ds: &Dataset,
) -> Result<EvalResult> {
    let (sel_w, sel_x) = sel.to_onehot(&exec.manifest)?;
    eval_graph(exec, state, ds, "eval", Some((sel_w, sel_x)))
}

/// Evaluate the full-precision network over `ds`.
pub fn eval_fp(exec: &mut StepExecutor, state: &mut StateVec, ds: &Dataset) -> Result<EvalResult> {
    eval_graph(exec, state, ds, "fp_eval", None)
}

fn eval_graph(
    exec: &mut StepExecutor,
    state: &mut StateVec,
    ds: &Dataset,
    graph: &str,
    sel: Option<(Tensor, Tensor)>,
) -> Result<EvalResult> {
    let b = exec.manifest.batch_size;
    let n_batches = ds.len() / b;
    assert!(n_batches > 0, "dataset smaller than one batch");
    let mut total_loss = 0.0f64;
    let mut total_correct = 0.0f64;
    for i in 0..n_batches {
        let idx: Vec<usize> = (i * b..(i + 1) * b).collect();
        let (x, y) = ds.gather(&idx);
        let mut io = vec![("x".to_string(), x), ("y".to_string(), y)];
        if let Some((sw, sx)) = &sel {
            io.push(("sel_w".to_string(), sw.clone()));
            io.push(("sel_x".to_string(), sx.clone()));
        }
        let m = exec.step(graph, state, &io)?;
        total_loss += metric_f32(&m, "loss")? as f64;
        total_correct += metric_f32(&m, "correct")? as f64;
    }
    let samples = n_batches * b;
    Ok(EvalResult {
        loss: total_loss / n_batches as f64,
        accuracy: total_correct / samples as f64,
        samples,
    })
}

/// Teacher logits for one batch via the FP graph (label refinery, §B.2).
/// Inference has no sharded lowering — this rides the serial engine path.
pub fn teacher_logits(
    exec: &mut StepExecutor,
    fp_state: &mut StateVec,
    x: &Tensor,
) -> Result<Tensor> {
    let io = vec![("x".to_string(), x.clone())];
    let m = exec.run("fp_infer", fp_state, &io)?;
    m.get("logits")
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("fp_infer returned no logits"))
}
