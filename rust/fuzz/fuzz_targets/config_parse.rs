//! TOML config parse + typed `RunConfig` extraction on arbitrary
//! bytes.  Body shared with tier-1 via `ebs::fuzzing`.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    ebs::fuzzing::fuzz_config_parse(data);
});
