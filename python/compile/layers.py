"""L2 building blocks: convolution, batch norm, and EBS-quantized conv.

All tensors are NHWC; conv weights are HWIO.  The quantized conv is the
paper's Eq. 7: both the weight tensor and the input activation tensor are
aggregated over the candidate-bitwidth branches with externally supplied
coefficient vectors, then ONE convolution runs — the coefficients are
softmax(r)/softmax(s) during search, Gumbel-softmax during stochastic
search, and exact one-hots during retrain/eval (DESIGN.md §7.2).
"""

from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .kernels import ebs, ref

# Artifacts embed the Pallas kernels (the L1 layer); tests flip this to
# compare the pure-jnp oracle path end-to-end.
USE_PALLAS = os.environ.get("EBS_USE_PALLAS", "1") == "1"

BN_MOMENTUM = 0.9
BN_EPS = 1e-5


def conv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """SAME-padded 2D convolution, NHWC × HWIO → NHWC."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def batch_norm(
    x: jnp.ndarray,
    gamma: jnp.ndarray,
    beta: jnp.ndarray,
    mean: jnp.ndarray,
    var: jnp.ndarray,
    train: bool,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Batch norm over N,H,W.  Returns (y, new_mean, new_var).

    Train mode normalizes with batch statistics and exponentially updates
    the running stats (momentum 0.9); eval mode uses the running stats
    and returns them unchanged.
    """
    if train:
        mu = jnp.mean(x, axis=(0, 1, 2))
        sig2 = jnp.var(x, axis=(0, 1, 2))
        y = (x - mu) / jnp.sqrt(sig2 + BN_EPS)
        new_mean = BN_MOMENTUM * mean + (1.0 - BN_MOMENTUM) * mu
        new_var = BN_MOMENTUM * var + (1.0 - BN_MOMENTUM) * sig2
        return gamma * y + beta, new_mean, new_var
    y = (x - mean) / jnp.sqrt(var + BN_EPS)
    return gamma * y + beta, mean, var


def ebs_weight(w: jnp.ndarray, pw: jnp.ndarray, bits: Tuple[int, ...]) -> jnp.ndarray:
    """Aggregated quantized weights (Eq. 6); Pallas kernel or jnp oracle."""
    if USE_PALLAS:
        return ebs.ebs_weight_quant(w, pw, bits)
    return ref.ebs_weight_quant(w, pw, bits)


def ebs_act(
    x: jnp.ndarray, px: jnp.ndarray, alpha: jnp.ndarray, bits: Tuple[int, ...]
) -> jnp.ndarray:
    """Aggregated quantized activations (Eq. 17); Pallas or jnp oracle."""
    if USE_PALLAS:
        return ebs.ebs_act_quant(x, px, alpha, bits)
    return ref.ebs_act_quant(x, px, alpha, bits)


def qconv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    pw: jnp.ndarray,
    px: jnp.ndarray,
    alpha: jnp.ndarray,
    bits: Tuple[int, ...],
    stride: int = 1,
) -> jnp.ndarray:
    """Eq. 7: one convolution over aggregated quantized weights & acts."""
    xq = ebs_act(x, px, alpha, bits)
    wq = ebs_weight(w, pw, bits)
    return conv2d(xq, wq, stride)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy with integer labels."""
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def distill_loss(logits: jnp.ndarray, teacher_logits: jnp.ndarray) -> jnp.ndarray:
    """KL(teacher ‖ student) — the label-refinery objective (§B.2/Table 2)."""
    pt = jax.nn.softmax(teacher_logits)
    return jnp.mean(
        jnp.sum(pt * (jax.nn.log_softmax(teacher_logits) - jax.nn.log_softmax(logits)), axis=1)
    )


def accuracy_count(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Number of correct top-1 predictions in the batch (f32 scalar)."""
    return jnp.sum((jnp.argmax(logits, axis=1) == labels).astype(jnp.float32))
