//! Shared helpers for the integration tests.

use std::path::PathBuf;

use ebs::runtime::Engine;

pub fn artifacts_dir(model: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(model)
}

/// Open an engine for `model` on whatever backend this build supports:
/// PJRT when real bindings + artifacts exist, otherwise the native
/// interpreter (synthesized manifest, no artifacts needed).  Never
/// skips — the step-graph integration tests run everywhere since the
/// native backend landed (DESIGN.md §11).
#[allow(dead_code)]
pub fn open_engine(model: &str) -> Engine {
    Engine::open(&artifacts_dir(model)).expect("open engine (native fallback)")
}

/// Artifact-only entry point for tests that specifically need the real
/// PJRT path (full-fidelity HLO execution); skips under the stub.
#[allow(dead_code)]
pub fn open_pjrt_or_skip(model: &str) -> Option<Engine> {
    if !ebs::runtime::backend_available() {
        eprintln!("[skip] real XLA backend unavailable (offline stub build)");
        return None;
    }
    let dir = artifacts_dir(model);
    if !dir.join("manifest.json").exists() {
        eprintln!("[skip] artifacts for {model} missing — run `make artifacts` first");
        return None;
    }
    Some(Engine::open(&dir).unwrap())
}
