//! DNAS supernet efficiency harness (Table 3).
//!
//! Runs N iterations of the `dnas_search` graph (N weight copies, N²
//! convolutions per layer — Fig. 2a) and of the EBS `search_det` graph
//! (one copy, one convolution — Fig. 2b) on identical data, recording
//! wall-clock and peak RSS.  The O(N)/O(N²) vs O(1)/O(1) gap is the
//! paper's Table 3 claim; see `report::table3` for the assembled table.

use std::time::Instant;

use anyhow::Result;

use crate::runtime::{Engine, StateVec, Tensor};
use crate::util::{mem, Rng};

/// Measured cost of running `iters` search iterations on one graph.
#[derive(Debug, Clone)]
pub struct StepCost {
    pub graph: String,
    pub iters: usize,
    pub total_seconds: f64,
    pub peak_rss_bytes: u64,
    pub state_bytes: usize,
}

/// Shared body of the search-step timing harness (Table 3 and the
/// shards sweep ride the same protocol): a seeded random-batch stream,
/// the fixed step-io literal, one untimed warmup step, then `iters`
/// timed steps through `step`.  One copy of the io keys and
/// hyperparameters, however the step is dispatched.
fn timed_search_steps(
    image: [usize; 3],
    batch: usize,
    classes: usize,
    iters: usize,
    seed: u64,
    step: &mut dyn FnMut(&[(String, Tensor)]) -> Result<()>,
) -> Result<f64> {
    let mut rng = Rng::new(seed);
    let [h, w, c] = image;
    let draw = move |rng: &mut Rng| -> (Tensor, Tensor) {
        (
            Tensor::from_f32(
                &[batch, h, w, c],
                (0..batch * h * w * c).map(|_| rng.normal()).collect(),
            ),
            Tensor::from_i32(&[batch], (0..batch).map(|_| rng.below(classes) as i32).collect()),
        )
    };
    let io = |xt: Tensor, yt: Tensor, xv: Tensor, yv: Tensor| {
        vec![
            ("xt".to_string(), xt),
            ("yt".to_string(), yt),
            ("xv".to_string(), xv),
            ("yv".to_string(), yv),
            ("lr_w".to_string(), Tensor::scalar_f32(0.01)),
            ("lr_arch".to_string(), Tensor::scalar_f32(0.02)),
            ("wd".to_string(), Tensor::scalar_f32(5e-4)),
            ("lam".to_string(), Tensor::scalar_f32(0.5)),
            ("target".to_string(), Tensor::scalar_f32(1.0)),
        ]
    };
    // Warmup (compile on PJRT, arena/replica growth on native) outside
    // the timed region.
    let (xt, yt) = draw(&mut rng);
    let (xv, yv) = draw(&mut rng);
    step(&io(xt, yt, xv, yv))?;
    let t0 = Instant::now();
    for _ in 0..iters {
        let (xt, yt) = draw(&mut rng);
        let (xv, yv) = draw(&mut rng);
        step(&io(xt, yt, xv, yv))?;
    }
    Ok(t0.elapsed().as_secs_f64())
}

/// Execute `iters` steps of `graph` ("search_det" or "dnas_search") with
/// random batches; returns wall-clock + memory accounting.
pub fn run_dnas_steps(
    engine: &mut Engine,
    graph: &str,
    state: &mut StateVec,
    iters: usize,
    seed: u64,
) -> Result<StepCost> {
    engine.prepare(graph)?;
    let (image, b, classes) =
        (engine.manifest.image, engine.manifest.batch_size, engine.manifest.num_classes);
    let total_seconds = timed_search_steps(image, b, classes, iters, seed, &mut |io| {
        engine.run(graph, state, io)?;
        Ok(())
    })?;
    Ok(StepCost {
        graph: graph.to_string(),
        iters,
        total_seconds,
        peak_rss_bytes: mem::peak_rss_bytes(),
        state_bytes: state.size_bytes(),
    })
}

/// [`run_dnas_steps`] through the sharded step executor — the
/// shards-sweep half of the `search_step` bench (DESIGN.md §14): the
/// identical step protocol, each step dispatched via
/// [`crate::exec::StepExecutor::step`] so it fans out over the
/// configured replicas.
pub fn run_sharded_search_steps(
    exec: &mut crate::exec::StepExecutor,
    state: &mut StateVec,
    iters: usize,
    seed: u64,
) -> Result<StepCost> {
    let (image, b, classes) =
        (exec.manifest.image, exec.manifest.batch_size, exec.manifest.num_classes);
    let total_seconds = timed_search_steps(image, b, classes, iters, seed, &mut |io| {
        exec.step("search_det", state, io)?;
        Ok(())
    })?;
    Ok(StepCost {
        graph: "search_det".to_string(),
        iters,
        total_seconds,
        peak_rss_bytes: mem::peak_rss_bytes(),
        state_bytes: state.size_bytes(),
    })
}

/// Analytic memory model (the structural part of Table 3): bytes of
/// meta-weight copies held by each method for N candidate bitwidths.
pub fn weight_copy_bytes(engine: &Engine, n_candidates: usize) -> (usize, usize) {
    // EBS: one meta copy per quantized conv; DNAS: N copies (§4.1).
    let one: usize = engine
        .manifest
        .state_spec
        .iter()
        .filter(|l| {
            l.path.starts_with("state/params/")
                && l.path.ends_with("/w")
                && !l.path.contains("stem")
                && !l.path.contains("fc")
        })
        .map(|l| l.num_elements() * 4)
        .sum();
    (one, one * n_candidates)
}
