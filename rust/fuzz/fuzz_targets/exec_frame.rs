//! Exec cluster wire protocol (DESIGN.md §18): framing + message
//! decode on arbitrary bytes must yield typed errors, never a panic or
//! unbounded allocation, and encode∘decode must be byte-stable.  Body
//! shared with tier-1 via `ebs::fuzzing`.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    ebs::fuzzing::fuzz_exec_frame(data);
});
