//! SIMD-tier differential property tests (ISSUE 8, DESIGN.md §17).
//!
//! Every kernel tier this host can run must be **bit-identical** to the
//! scalar reference — the reduction is exact integer arithmetic, so
//! equality is checked with `==`, never a tolerance.  The sweep covers
//! the word-length classes where vector kernels diverge structurally
//! from scalar code:
//!
//! * sub-word `s` (1, 7, 33 bits — a single masked word),
//! * word-exact `s` (64, 128 bits),
//! * word-straddling `s` (63, 65, 257 bits — partial final word),
//! * Harley–Seal-block `s` (4096 = 64 words exactly; 4100 = HS block
//!   plus a 4-bit tail, so the AVX2 path runs all three of its stages:
//!   CSA blocks, remainder vectors, scalar tail words).
//!
//! Each shape runs through the serial, tiled, and threaded GEMM paths
//! at every available tier, and the raw popcount kernels are swept
//! directly across all word counts 0..=130.

use ebs::bd::gemm::{
    fused, fused_tier, fused_tiled_tier, naive_codes_matmul, par_fused_tier, GemmTiles,
};
use ebs::bd::simd::{self, KernelTier};
use ebs::bd::{pack_cols, pack_rows};
use ebs::util::Rng;

/// GEMM cases at one `s`: random M/K-bit codes, checked against the
/// naive integer matmul for every available tier × tiling × threads.
fn sweep_s(rng: &mut Rng, s: usize, mb: u32, kb: u32) {
    // Keep co·n small: the point is the inner reduction length, and
    // s = 4096+ cases would otherwise dominate test time.
    let (co, n) = (3usize, 4usize);
    let wq: Vec<u8> = (0..co * s).map(|_| rng.below(1 << mb) as u8).collect();
    let xq: Vec<u8> = (0..s * n).map(|_| rng.below(1 << kb) as u8).collect();
    let expect = naive_codes_matmul(&wq, &xq, co, s, n);
    let bw = pack_rows(&wq, co, s, mb);
    let (bx, _) = pack_cols(&xq, s, n, kb);

    assert_eq!(fused(&bw, &bx, co, n, mb, kb), expect, "dispatched fused s={s} M={mb} K={kb}");
    for tier in simd::available_tiers() {
        assert_eq!(
            fused_tier(&bw, &bx, co, n, mb, kb, tier),
            expect,
            "fused[{tier}] s={s} M={mb} K={kb}"
        );
        for tiles in [GemmTiles::new(1, 1), GemmTiles::new(2, 3), GemmTiles::default()] {
            assert_eq!(
                fused_tiled_tier(&bw, &bx, co, n, mb, kb, tiles, tier),
                expect,
                "tiled[{tier}] s={s} M={mb} K={kb} {tiles:?}"
            );
            for threads in [1usize, 2, 8] {
                assert_eq!(
                    par_fused_tier(&bw, &bx, co, n, mb, kb, tiles, threads, tier),
                    expect,
                    "par[{tier}] s={s} M={mb} K={kb} T={threads} {tiles:?}"
                );
            }
        }
    }
}

#[test]
fn every_tier_matches_naive_on_subword_s() {
    let mut rng = Rng::new(0x51D0);
    for &s in &[1usize, 7, 33] {
        sweep_s(&mut rng, s, 2, 3);
        sweep_s(&mut rng, s, 5, 5);
    }
}

#[test]
fn every_tier_matches_naive_on_word_exact_s() {
    let mut rng = Rng::new(0x51D1);
    for &s in &[64usize, 128] {
        sweep_s(&mut rng, s, 2, 2);
        sweep_s(&mut rng, s, 4, 3);
    }
}

#[test]
fn every_tier_matches_naive_on_word_straddling_s() {
    let mut rng = Rng::new(0x51D2);
    for &s in &[63usize, 65, 257] {
        sweep_s(&mut rng, s, 1, 2);
        sweep_s(&mut rng, s, 3, 4);
    }
}

#[test]
fn every_tier_matches_naive_on_harley_seal_block_s() {
    let mut rng = Rng::new(0x51D3);
    // 4096 bits = 64 words = exactly one AVX2 Harley–Seal block;
    // 4100 adds a sub-word tail so every stage of the kernel runs.
    sweep_s(&mut rng, 4096, 2, 2);
    sweep_s(&mut rng, 4100, 3, 1);
}

/// The raw popcount kernels across every word count 0..=130 (spanning
/// all vector-tail lengths of every tier), on dense random rows.
#[test]
fn raw_kernels_match_scalar_on_all_word_counts() {
    let mut rng = Rng::new(0x51D4);
    for tier in simd::available_tiers() {
        let f = simd::kernel_for(tier).expect("available tier must have a kernel");
        for words in 0usize..=130 {
            let a: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
            let b: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
            assert_eq!(f(&a, &b), simd::scalar(&a, &b), "tier {tier}, {words} words");
        }
    }
}

/// The portable tier is unconditionally available — the guarantee the
/// forced-fallback path (`EBS_FORCE_SCALAR=1`, see
/// `tests/simd_forced_fallback.rs`) rests on.
#[test]
fn scalar_tier_is_always_present() {
    let tiers = simd::available_tiers();
    assert_eq!(tiers.first(), Some(&KernelTier::Scalar));
    assert!(simd::kernel_for(KernelTier::Scalar).is_some());
    // The auto-selected tier is always one of the available ones.
    assert!(tiers.contains(&simd::active_tier()));
}
