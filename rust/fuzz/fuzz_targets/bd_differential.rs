//! Differential GEMM: arbitrary (shape, bit pair, tiles, threads)
//! cases where the two-stage, fused, tiled and parallel AND+POPCNT
//! paths must all match the naive integer reference bit-for-bit.
//! Body shared with tier-1 via `ebs::fuzzing`.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    ebs::fuzzing::fuzz_bd_differential(data);
});
