//! The native CPU [`Backend`]: interprets every step graph the PJRT
//! artifacts export — `init`, `fp_train`, `fp_eval`, `fp_infer`,
//! `train`, `eval`, `infer`, `search_det`, `search_sto` — in pure Rust
//! (DESIGN.md §11).
//!
//! Bilevel semantics follow `python/compile/steps.py` exactly: the
//! weight phase (Eq. 10) runs SGD-momentum over (params, α) on the
//! train batch and commits the BN running-stat updates; the arch phase
//! (Eq. 9) runs Adam over (r, s) on the validation batch with the
//! relative-overshoot FLOPs hinge `λ·relu(E[FLOPs] − target)/target`,
//! using batch statistics but *not* committing them (DARTS practice).
//! Gumbel noise arrives as graph inputs (`g_r`, `g_s`, `tau`) so the
//! coordinator keeps ownership of all randomness.
//!
//! The backend owns one step-persistent [`TapeArena`]/[`Grads`] pair
//! (DESIGN.md §12): every graph dispatch reuses the same grow-once
//! buffers, so the steady-state search step performs no tape/gradient
//! allocation.  `set_threads` fans the conv/BN/quant kernels out over
//! the shared `kernels` partitioner — results are bit-identical at any
//! thread count, so threading never perturbs the same-seed replay
//! guarantee.
//!
//! `set_shards` additionally fans whole train/search/eval *steps* out
//! over data-parallel replicas (`run_sharded`, DESIGN.md §14).  Where
//! those replicas live is the transport's business (DESIGN.md §18):
//! every sharded phase goes through the backend's
//! [`ChunkTransport`] — the in-process scoped-thread pool by default,
//! or a coordinator/worker-process cluster via `set_transport` — and
//! comes back as per-chunk partials combined in canonical chunk order
//! before the single optimizer update here.  Bit-identical results at
//! any shard/worker count under a fixed chunking.

use std::collections::HashMap;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::flops::{FlopsModel, MIXED_DIVISOR};
use crate::exec::transport::BatchSource;
use crate::exec::{ChunkTransport, InProcessTransport, PhaseSpec, ShardSpec};
use crate::runtime::{Backend, Manifest, Metrics, StateVec, Tensor};
use crate::util::Rng;

use super::graph::{Coeffs, Grads, NativeNet, TapeArena};
use super::ops;
use super::optim;
use super::quant;

/// Pure-Rust interpreter for one model's step graphs.
pub struct NativeBackend {
    net: NativeNet,
    flops: FlopsModel,
    alpha_init: f32,
    num_classes: usize,
    arena: TapeArena,
    grads: Grads,
    /// Step-persistent softmax / logit-gradient buffers (B × classes).
    probs: Vec<f32>,
    teacher_probs: Vec<f32>,
    dlogits: Vec<f32>,
    /// Data-parallel sharding of the step graphs (DESIGN.md §14);
    /// inactive spec ⇒ the serial path below runs unchanged.
    shards: ShardSpec,
    /// Where the sharded-phase replicas run (DESIGN.md §18): the
    /// in-process pool by default, a worker cluster via
    /// [`NativeBackend::set_transport`].
    transport: Box<dyn ChunkTransport>,
}

/// Gumbel-noise inputs of one stochastic step: ((L,N) rows for r and s,
/// temperature τ).
struct StoInputs<'a> {
    g_r: &'a [f32],
    g_s: &'a [f32],
    tau: f32,
}

fn io_get<'a>(io: &'a [(String, Tensor)], name: &str) -> Result<&'a Tensor> {
    io.iter()
        .find(|(k, _)| k == name)
        .map(|(_, t)| t)
        .with_context(|| format!("native graph needs input '{name}'"))
}

fn io_f32<'a>(io: &'a [(String, Tensor)], name: &str) -> Result<&'a [f32]> {
    io_get(io, name)?.as_f32()
}

fn io_scalar(io: &[(String, Tensor)], name: &str) -> Result<f32> {
    io_get(io, name)?.item_f32()
}

/// Optional index side-channel for a batch input: a `{name}_src` io
/// entry carrying `[dataset_id, idx0, idx1, …]` as f32 (exact for
/// integers ≤ 2²⁴ — far beyond any dataset here).  Drivers attach it
/// when the batch came from a transport-hosted dataset so the cluster
/// transport can ship indices instead of pixels (DESIGN.md §18);
/// absence means payload mode.  Backends and graphs that don't know
/// the key ignore extra io entries, so attaching is always safe.
fn io_source(
    io: &[(String, Tensor)],
    name: &str,
    batch: usize,
) -> Result<Option<(u32, Vec<u32>)>> {
    let key = format!("{name}_src");
    let Some((_, t)) = io.iter().find(|(k, _)| *k == key) else {
        return Ok(None);
    };
    let v = t.as_f32()?;
    ensure!(
        v.len() == batch + 1,
        "'{key}' carries {} values, expected dataset id + {batch} indices",
        v.len()
    );
    Ok(Some((v[0] as u32, v[1..].iter().map(|&f| f as u32).collect())))
}

/// Borrow an [`io_source`] result as the [`BatchSource`] a `PhaseSpec`
/// wants.
fn as_source(parsed: &Option<(u32, Vec<u32>)>) -> Option<BatchSource<'_>> {
    parsed.as_ref().map(|(d, v)| BatchSource { dataset: *d, idx: v })
}

impl NativeBackend {
    pub fn from_manifest(m: &Manifest) -> Result<NativeBackend> {
        Ok(NativeBackend {
            net: NativeNet::from_manifest(m)?,
            flops: FlopsModel::from_manifest(m)?,
            alpha_init: m.alpha_init,
            num_classes: m.num_classes,
            arena: TapeArena::new(),
            grads: Grads::default(),
            probs: Vec::new(),
            teacher_probs: Vec::new(),
            dlogits: Vec::new(),
            shards: ShardSpec::serial(),
            transport: Box::new(InProcessTransport::new()),
        })
    }

    /// Swap the replica transport (DESIGN.md §18) — e.g. to a
    /// `ClusterTransport` with dialed-in workers.  The numerics
    /// contract is transport-independent, so this never changes
    /// results, only where the replicas run.
    pub fn set_transport(&mut self, transport: Box<dyn ChunkTransport>) {
        self.transport = transport;
    }

    /// Arena reuse accounting (tests assert `grows` freezes after the
    /// first step at a given shape).
    pub fn scratch_stats(&self) -> crate::bd::ScratchStats {
        self.arena.stats
    }

    /// Split (L, N) selection/coefficient matrices into per-layer rows.
    fn coeff_rows(&self, flat: &[f32]) -> Result<Vec<Vec<f32>>> {
        let l = self.net.desc.qconv_names.len();
        let n = self.net.bits.len();
        ensure!(flat.len() == l * n, "coefficient matrix is {} not {l}×{n}", flat.len());
        Ok(flat.chunks_exact(n).map(|r| r.to_vec()).collect())
    }

    /// Branch coefficients from the state strengths: softmax (Eq. 5) or
    /// Gumbel-softmax (Eq. 8) when noise is supplied.
    fn coeffs_from_state(&self, state: &StateVec, sto: Option<&StoInputs>) -> Result<Coeffs> {
        let n = self.net.bits.len();
        let mut cw = Vec::new();
        let mut cx = Vec::new();
        for (i, name) in self.net.desc.qconv_names.iter().enumerate() {
            let r = state.get(&format!("state/arch/r/{name}"))?.as_f32()?;
            let s = state.get(&format!("state/arch/s/{name}"))?.as_f32()?;
            let (mut pw, mut px) = (Vec::new(), Vec::new());
            match sto {
                None => {
                    quant::softmax(r, &mut pw);
                    quant::softmax(s, &mut px);
                }
                Some(g) => {
                    quant::gumbel_softmax(r, &g.g_r[i * n..(i + 1) * n], g.tau, &mut pw);
                    quant::gumbel_softmax(s, &g.g_s[i * n..(i + 1) * n], g.tau, &mut px);
                }
            }
            cw.push(pw);
            cx.push(px);
        }
        Ok(Coeffs { cw, cx })
    }

    /// Eq. 11 expected cost of a coefficient assignment, in MFLOPs.
    fn expected_mflops(&self, c: &Coeffs) -> f64 {
        let n = self.net.bits.len();
        let flat = |rows: &[Vec<f32>]| -> Vec<f32> {
            let mut v = Vec::with_capacity(rows.len() * n);
            for r in rows {
                v.extend_from_slice(r);
            }
            v
        };
        self.flops.expected_mflops(&flat(&c.cw), &flat(&c.cx))
    }

    /// Eq. 10: one SGD-momentum update of (params, α) on a batch.
    /// Returns (loss, batch accuracy); loss/acc are computed at the
    /// pre-update parameters, as in the exported graphs.
    #[allow(clippy::too_many_arguments)]
    fn weight_phase(
        &mut self,
        state: &mut StateVec,
        coeffs: Option<&Coeffs>,
        x: &[f32],
        y: &[i32],
        lr: f32,
        wd: f32,
        teacher: Option<(&[f32], f32)>,
    ) -> Result<(f32, f32)> {
        let batch = y.len();
        let classes = self.num_classes;
        self.net.forward(state, coeffs, x, batch, true, &mut self.arena)?;
        let logits = &self.arena.tape.logits;
        let ce = ops::cross_entropy(logits, y, classes);
        ops::softmax_rows(logits, batch, classes, &mut self.probs);

        let (loss, mu, have_teacher) = match teacher {
            Some((t_logits, mu)) if mu > 0.0 => {
                let kl = ops::distill_loss(logits, t_logits, batch, classes);
                ops::softmax_rows(t_logits, batch, classes, &mut self.teacher_probs);
                ((1.0 - mu) * ce + mu * kl, mu, true)
            }
            _ => (ce, 0.0, false),
        };

        let inv_b = 1.0 / batch as f32;
        self.dlogits.clear();
        self.dlogits.resize(batch * classes, 0.0);
        for b in 0..batch {
            for c in 0..classes {
                let i = b * classes + c;
                let hard = self.probs[i] - if y[b] as usize == c { 1.0 } else { 0.0 };
                let soft = if have_teacher {
                    self.probs[i] - self.teacher_probs[i]
                } else {
                    0.0
                };
                self.dlogits[i] = ((1.0 - mu) * hard + mu * soft) * inv_b;
            }
        }

        self.net.backward(state, coeffs, &mut self.arena, &self.dlogits, &mut self.grads)?;
        self.arena.bn_updates.apply(state)?;
        optim::sgd_momentum_step(state, &self.grads.by_path, lr, wd)?;
        let acc = ops::correct_count(&self.arena.tape.logits, y, classes) * inv_b;
        Ok((loss, acc))
    }

    /// Eq. 9: one Adam update of (r, s) on the validation batch with
    /// the FLOPs hinge.  Returns (val CE, correct count, E[FLOPs]).
    #[allow(clippy::too_many_arguments)]
    fn arch_phase(
        &mut self,
        state: &mut StateVec,
        sto: Option<&StoInputs>,
        xv: &[f32],
        yv: &[i32],
        lr_arch: f32,
        lam: f32,
        target: f32,
    ) -> Result<(f32, f32, f32)> {
        let batch = yv.len();
        let classes = self.num_classes;
        let coeffs = self.coeffs_from_state(state, sto)?;
        // validation forward with batch statistics; BN updates dropped.
        self.net.forward(state, Some(&coeffs), xv, batch, true, &mut self.arena)?;
        let logits = &self.arena.tape.logits;
        let val_ce = ops::cross_entropy(logits, yv, classes);
        let correct = ops::correct_count(logits, yv, classes);
        let eflops = self.expected_mflops(&coeffs);

        ops::softmax_rows(logits, batch, classes, &mut self.probs);
        let inv_b = 1.0 / batch as f32;
        self.dlogits.clear();
        self.dlogits.resize(batch * classes, 0.0);
        for b in 0..batch {
            for c in 0..classes {
                let i = b * classes + c;
                self.dlogits[i] =
                    (self.probs[i] - if yv[b] as usize == c { 1.0 } else { 0.0 }) * inv_b;
            }
        }
        self.net.backward(state, Some(&coeffs), &mut self.arena, &self.dlogits, &mut self.grads)?;

        self.apply_flops_hinge(&coeffs, eflops, lam, target);
        self.arch_strength_update(state, sto, &coeffs, lr_arch)?;
        Ok((val_ce, correct, eflops as f32))
    }

    /// Eq. 9's FLOPs-hinge gradient (zero at or below target, like
    /// relu'), accumulated into the combined coefficient grads.  Shared
    /// by the serial and sharded arch phases — the hinge depends only on
    /// the coefficients, never on the batch, so it runs once on the
    /// combiner after the data-gradient reduction.
    fn apply_flops_hinge(&mut self, coeffs: &Coeffs, eflops: f64, lam: f32, target: f32) {
        if eflops > target as f64 && target > 0.0 {
            let scale = lam as f64 / target as f64;
            let bits = &self.net.bits;
            for (l, (_, macs)) in self.flops.qconv_macs.iter().enumerate() {
                let e_m: f64 = (0..bits.len())
                    .map(|j| coeffs.cw[l][j] as f64 * bits[j] as f64)
                    .sum();
                let e_k: f64 = (0..bits.len())
                    .map(|j| coeffs.cx[l][j] as f64 * bits[j] as f64)
                    .sum();
                let base = *macs as f64 / (MIXED_DIVISOR * 1e6);
                for j in 0..bits.len() {
                    self.grads.dcw[l][j] += (scale * base * bits[j] as f64 * e_k) as f32;
                    self.grads.dcx[l][j] += (scale * base * bits[j] as f64 * e_m) as f32;
                }
            }
        }
    }

    /// Coefficients → strengths (softmax / Gumbel-softmax VJP) over the
    /// combined `dcw`/`dcx`, then one Adam update of (r, s).  Shared by
    /// the serial and sharded arch phases.
    fn arch_strength_update(
        &mut self,
        state: &mut StateVec,
        sto: Option<&StoInputs>,
        coeffs: &Coeffs,
        lr_arch: f32,
    ) -> Result<()> {
        let n = self.net.bits.len();
        let mut arch_grads: HashMap<String, Vec<f32>> = HashMap::new();
        for (i, name) in self.net.desc.qconv_names.iter().enumerate() {
            let r = state.get(&format!("state/arch/r/{name}"))?.as_f32()?;
            let s = state.get(&format!("state/arch/s/{name}"))?.as_f32()?;
            let mut gr = vec![0f32; n];
            let mut gs = vec![0f32; n];
            match sto {
                None => {
                    quant::softmax_backward(&coeffs.cw[i], &self.grads.dcw[i], &mut gr);
                    quant::softmax_backward(&coeffs.cx[i], &self.grads.dcx[i], &mut gs);
                }
                Some(g) => {
                    quant::gumbel_softmax_backward(
                        r, &coeffs.cw[i], &self.grads.dcw[i], g.tau, &mut gr,
                    );
                    quant::gumbel_softmax_backward(
                        s, &coeffs.cx[i], &self.grads.dcx[i], g.tau, &mut gs,
                    );
                }
            }
            arch_grads.insert(format!("state/arch/r/{name}"), gr);
            arch_grads.insert(format!("state/arch/s/{name}"), gs);
        }
        optim::adam_step(state, &arch_grads, lr_arch)?;
        Ok(())
    }

    /// Sharded Eq. 10 weight phase: the transport fans the
    /// forward+backward out over its replicas (sync-BN moments through
    /// its rendezvous) and combines grads in canonical chunk order;
    /// the combiner here then commits the BN running-stat updates and
    /// applies one SGD-momentum update to the global state.
    #[allow(clippy::too_many_arguments)]
    fn weight_phase_sharded(
        &mut self,
        state: &mut StateVec,
        coeffs: Option<&Coeffs>,
        x: &[f32],
        y: &[i32],
        lr: f32,
        wd: f32,
        teacher: Option<(&[f32], f32)>,
        source: Option<BatchSource<'_>>,
    ) -> Result<(f32, f32)> {
        let batch = y.len();
        let spec = PhaseSpec {
            train: true,
            backward: true,
            classes: self.num_classes,
            coeffs,
            x,
            y,
            source,
            teacher,
            shards: self.shards.shards,
            chunks: self.shards.chunks,
        };
        let out = self.transport.run_phase(&self.net, state, &spec, &mut self.grads)?;
        let ce = (out.ce_sum / batch as f64) as f32;
        let loss = match teacher {
            Some((_, mu)) if mu > 0.0 => {
                (1.0 - mu) * ce + mu * (out.kl_sum / batch as f64) as f32
            }
            _ => ce,
        };
        self.transport.commit_bn(state)?;
        optim::sgd_momentum_step(state, &self.grads.by_path, lr, wd)?;
        Ok((loss, out.correct / batch as f32))
    }

    /// Sharded Eq. 9 arch phase: the validation forward+backward fans
    /// out like the weight phase (batch statistics, updates dropped by
    /// not committing them); the FLOPs hinge and the softmax VJP +
    /// Adam update run once here over the combined coefficient grads.
    #[allow(clippy::too_many_arguments)]
    fn arch_phase_sharded(
        &mut self,
        state: &mut StateVec,
        sto: Option<&StoInputs>,
        xv: &[f32],
        yv: &[i32],
        lr_arch: f32,
        lam: f32,
        target: f32,
        source: Option<BatchSource<'_>>,
    ) -> Result<(f32, f32, f32)> {
        let batch = yv.len();
        let coeffs = self.coeffs_from_state(state, sto)?;
        let spec = PhaseSpec {
            train: true,
            backward: true,
            classes: self.num_classes,
            coeffs: Some(&coeffs),
            x: xv,
            y: yv,
            source,
            teacher: None,
            shards: self.shards.shards,
            chunks: self.shards.chunks,
        };
        let out = self.transport.run_phase(&self.net, state, &spec, &mut self.grads)?;
        let val_ce = (out.ce_sum / batch as f64) as f32;
        let eflops = self.expected_mflops(&coeffs);
        self.apply_flops_hinge(&coeffs, eflops, lam, target);
        self.arch_strength_update(state, sto, &coeffs, lr_arch)?;
        Ok((val_ce, out.correct, eflops as f32))
    }

    /// Sharded eval forward (eval-mode BN — no moment exchange needed):
    /// per-chunk loss/correct partials combined in chunk order.
    fn eval_graph_sharded(
        &mut self,
        state: &StateVec,
        coeffs: Option<&Coeffs>,
        io: &[(String, Tensor)],
    ) -> Result<Metrics> {
        let x = io_f32(io, "x")?;
        let y = io_get(io, "y")?.as_i32()?;
        let batch = y.len();
        let src = io_source(io, "x", batch)?;
        let spec = PhaseSpec {
            train: false,
            backward: false,
            classes: self.num_classes,
            coeffs,
            x,
            y,
            source: as_source(&src),
            teacher: None,
            shards: self.shards.shards,
            chunks: self.shards.chunks,
        };
        let out = self.transport.run_phase(&self.net, state, &spec, &mut self.grads)?;
        let mut m = Metrics::new();
        m.insert("loss".into(), Tensor::scalar_f32((out.ce_sum / batch as f64) as f32));
        m.insert("correct".into(), Tensor::scalar_f32(out.correct));
        Ok(m)
    }

    /// The sharded search step: both bilevel phases fan out; every
    /// state mutation (BN commit, SGD, Adam) happens on the combiner
    /// between phases, so replicas only ever read the state.
    fn search_graph_sharded(
        &mut self,
        state: &mut StateVec,
        io: &[(String, Tensor)],
        stochastic: bool,
    ) -> Result<Metrics> {
        let xt = io_f32(io, "xt")?;
        let yt = io_get(io, "yt")?.as_i32()?;
        let xv = io_f32(io, "xv")?;
        let yv = io_get(io, "yv")?.as_i32()?;
        let lr_w = io_scalar(io, "lr_w")?;
        let lr_arch = io_scalar(io, "lr_arch")?;
        let wd = io_scalar(io, "wd")?;
        let lam = io_scalar(io, "lam")?;
        let target = io_scalar(io, "target")?;
        let sto_inputs;
        let sto = if stochastic {
            sto_inputs = StoInputs {
                g_r: io_f32(io, "g_r")?,
                g_s: io_f32(io, "g_s")?,
                tau: io_scalar(io, "tau")?,
            };
            Some(&sto_inputs)
        } else {
            None
        };

        let ti = io_source(io, "xt", yt.len())?;
        let vi = io_source(io, "xv", yv.len())?;
        let coeffs = self.coeffs_from_state(state, sto)?;
        let (train_loss, _) = self.weight_phase_sharded(
            state, Some(&coeffs), xt, yt, lr_w, wd, None, as_source(&ti),
        )?;
        let (val_loss, correct, eflops) =
            self.arch_phase_sharded(state, sto, xv, yv, lr_arch, lam, target, as_source(&vi))?;

        let mut m = Metrics::new();
        m.insert("eflops".into(), Tensor::scalar_f32(eflops));
        m.insert("train_loss".into(), Tensor::scalar_f32(train_loss));
        m.insert("val_loss".into(), Tensor::scalar_f32(val_loss));
        m.insert("val_acc".into(), Tensor::scalar_f32(correct / yv.len() as f32));
        Ok(m)
    }

    fn eval_graph(
        &mut self,
        state: &StateVec,
        coeffs: Option<&Coeffs>,
        io: &[(String, Tensor)],
    ) -> Result<Metrics> {
        let x = io_f32(io, "x")?;
        let y = io_get(io, "y")?.as_i32()?;
        self.net.forward(state, coeffs, x, y.len(), false, &mut self.arena)?;
        let logits = &self.arena.tape.logits;
        let mut m = Metrics::new();
        m.insert(
            "loss".into(),
            Tensor::scalar_f32(ops::cross_entropy(logits, y, self.num_classes)),
        );
        m.insert(
            "correct".into(),
            Tensor::scalar_f32(ops::correct_count(logits, y, self.num_classes)),
        );
        Ok(m)
    }

    fn infer_graph(
        &mut self,
        state: &StateVec,
        coeffs: Option<&Coeffs>,
        io: &[(String, Tensor)],
    ) -> Result<Metrics> {
        let x = io_get(io, "x")?;
        ensure!(x.shape().len() == 4, "infer input must be (B,H,W,C), got {:?}", x.shape());
        let batch = x.shape()[0];
        self.net.forward(state, coeffs, x.as_f32()?, batch, false, &mut self.arena)?;
        let mut m = Metrics::new();
        m.insert(
            "logits".into(),
            Tensor::from_f32(&[batch, self.num_classes], self.arena.tape.logits.clone()),
        );
        Ok(m)
    }

    fn search_graph(
        &mut self,
        state: &mut StateVec,
        io: &[(String, Tensor)],
        stochastic: bool,
    ) -> Result<Metrics> {
        let xt = io_f32(io, "xt")?;
        let yt = io_get(io, "yt")?.as_i32()?;
        let xv = io_f32(io, "xv")?;
        let yv = io_get(io, "yv")?.as_i32()?;
        let lr_w = io_scalar(io, "lr_w")?;
        let lr_arch = io_scalar(io, "lr_arch")?;
        let wd = io_scalar(io, "wd")?;
        let lam = io_scalar(io, "lam")?;
        let target = io_scalar(io, "target")?;
        let sto_inputs;
        let sto = if stochastic {
            sto_inputs = StoInputs {
                g_r: io_f32(io, "g_r")?,
                g_s: io_f32(io, "g_s")?,
                tau: io_scalar(io, "tau")?,
            };
            Some(&sto_inputs)
        } else {
            None
        };

        // One Gumbel sample (or the softmax coefficients) is shared by
        // both phases; arch is untouched by the weight phase, so the
        // coefficient values agree with steps.py's single computation.
        let coeffs = self.coeffs_from_state(state, sto)?;
        let (train_loss, _) =
            self.weight_phase(state, Some(&coeffs), xt, yt, lr_w, wd, None)?;
        let (val_loss, correct, eflops) =
            self.arch_phase(state, sto, xv, yv, lr_arch, lam, target)?;

        let mut m = Metrics::new();
        m.insert("eflops".into(), Tensor::scalar_f32(eflops));
        m.insert("train_loss".into(), Tensor::scalar_f32(train_loss));
        m.insert("val_loss".into(), Tensor::scalar_f32(val_loss));
        m.insert(
            "val_acc".into(),
            Tensor::scalar_f32(correct / yv.len() as f32),
        );
        Ok(m)
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn set_threads(&mut self, threads: usize) {
        self.net.threads = threads;
    }

    fn set_shards(&mut self, spec: ShardSpec) {
        self.shards = spec;
    }

    fn set_transport(&mut self, transport: Box<dyn ChunkTransport>) -> Result<()> {
        NativeBackend::set_transport(self, transport);
        Ok(())
    }

    fn host_dataset(&mut self, id: u32, ds: &crate::data::Dataset) -> Result<()> {
        self.transport.host_dataset(id, ds)
    }

    fn wire_stats(&self) -> Option<crate::exec::wire::WireTotals> {
        self.transport.wire_stats()
    }

    /// The sharded-step dispatch (DESIGN.md §14).  Train/search/eval
    /// graphs fan out over the configured replicas with shard-invariant
    /// chunked reductions; graphs without a sharded lowering (infer),
    /// and an inactive spec, fall back to the serial interpreter.
    fn run_sharded(
        &mut self,
        manifest: &Manifest,
        graph: &str,
        state: &mut StateVec,
        io: &[(String, Tensor)],
    ) -> Result<(Metrics, std::time::Duration)> {
        if !self.shards.active() {
            return self.run(manifest, graph, state, io);
        }
        let t0 = std::time::Instant::now();
        let metrics = match graph {
            "fp_train" => {
                let x = io_f32(io, "x")?;
                let y = io_get(io, "y")?.as_i32()?;
                let lr = io_scalar(io, "lr")?;
                let wd = io_scalar(io, "wd")?;
                let src = io_source(io, "x", y.len())?;
                let (loss, acc) = self.weight_phase_sharded(
                    state, None, x, y, lr, wd, None, as_source(&src),
                )?;
                let mut m = Metrics::new();
                m.insert("loss".into(), Tensor::scalar_f32(loss));
                m.insert("acc".into(), Tensor::scalar_f32(acc));
                Ok(m)
            }
            "train" => {
                let coeffs = Coeffs {
                    cw: self.coeff_rows(io_f32(io, "sel_w")?)?,
                    cx: self.coeff_rows(io_f32(io, "sel_x")?)?,
                };
                let x = io_f32(io, "x")?;
                let y = io_get(io, "y")?.as_i32()?;
                let mu = io_scalar(io, "mu")?;
                let teacher = io_f32(io, "teacher")?;
                let lr = io_scalar(io, "lr")?;
                let wd = io_scalar(io, "wd")?;
                let src = io_source(io, "x", y.len())?;
                let (loss, acc) = self.weight_phase_sharded(
                    state, Some(&coeffs), x, y, lr, wd, Some((teacher, mu)),
                    as_source(&src),
                )?;
                let mut m = Metrics::new();
                m.insert("loss".into(), Tensor::scalar_f32(loss));
                m.insert("acc".into(), Tensor::scalar_f32(acc));
                Ok(m)
            }
            "search_det" => self.search_graph_sharded(state, io, false),
            "search_sto" => self.search_graph_sharded(state, io, true),
            "fp_eval" => self.eval_graph_sharded(state, None, io),
            "eval" => {
                let coeffs = Coeffs {
                    cw: self.coeff_rows(io_f32(io, "sel_w")?)?,
                    cx: self.coeff_rows(io_f32(io, "sel_x")?)?,
                };
                self.eval_graph_sharded(state, Some(&coeffs), io)
            }
            _ => return self.run(manifest, graph, state, io),
        }?;
        Ok((metrics, t0.elapsed()))
    }

    /// Mirror of `model.init_state`: He-normal conv weights, uniform fc,
    /// BN affine at (1, 0), running stats at (0, 1), α at its §B.3 init,
    /// strengths and optimizer slots at zero.  Driven by `util::Rng`
    /// instead of `jax.random`, so native and artifact initializations
    /// are distribution-equal but not bit-equal (DESIGN.md §11).
    fn init_state(&mut self, manifest: &Manifest, seed: i32) -> Result<StateVec> {
        let mut state = StateVec::zeros(&manifest.state_spec);
        let mut rng = Rng::new((seed as i64 as u64) ^ 0x0EB51417);
        for l in self.net.desc.inventory() {
            if l.kind == "fc" {
                let scale = 1.0 / (l.in_ch as f32).sqrt();
                let w = state.get_mut(&format!("state/params/{}/w", l.name))?.as_f32_mut()?;
                for v in w.iter_mut() {
                    *v = rng.uniform_in(-scale, scale);
                }
                continue;
            }
            let fan_in = (l.ksize * l.ksize * l.in_ch) as f32;
            let std = (2.0 / fan_in).sqrt();
            let w = state.get_mut(&format!("state/params/{}/w", l.name))?.as_f32_mut()?;
            for v in w.iter_mut() {
                *v = std * rng.normal();
            }
            state
                .get_mut(&format!("state/params/bn_{}/gamma", l.name))?
                .as_f32_mut()?
                .fill(1.0);
            state.get_mut(&format!("state/bn/{}/var", l.name))?.as_f32_mut()?.fill(1.0);
            if l.kind == "qconv" {
                state
                    .get_mut(&format!("state/alphas/{}", l.name))?
                    .as_f32_mut()?
                    .fill(self.alpha_init);
            }
        }
        Ok(state)
    }

    fn prepare(&mut self, _manifest: &Manifest, _graph: &str) -> Result<()> {
        Ok(())
    }

    fn run(
        &mut self,
        _manifest: &Manifest,
        graph: &str,
        state: &mut StateVec,
        io: &[(String, Tensor)],
    ) -> Result<(Metrics, std::time::Duration)> {
        // The interpreter has no marshalling/compile phases — the whole
        // dispatch IS the execution, so that is what gets reported.
        let t0 = std::time::Instant::now();
        let metrics = match graph {
            "fp_train" => {
                let x = io_f32(io, "x")?;
                let y = io_get(io, "y")?.as_i32()?;
                let lr = io_scalar(io, "lr")?;
                let wd = io_scalar(io, "wd")?;
                let (loss, acc) = self.weight_phase(state, None, x, y, lr, wd, None)?;
                let mut m = Metrics::new();
                m.insert("loss".into(), Tensor::scalar_f32(loss));
                m.insert("acc".into(), Tensor::scalar_f32(acc));
                Ok(m)
            }
            "train" => {
                let coeffs = Coeffs {
                    cw: self.coeff_rows(io_f32(io, "sel_w")?)?,
                    cx: self.coeff_rows(io_f32(io, "sel_x")?)?,
                };
                let x = io_f32(io, "x")?;
                let y = io_get(io, "y")?.as_i32()?;
                let mu = io_scalar(io, "mu")?;
                let teacher = io_f32(io, "teacher")?;
                let lr = io_scalar(io, "lr")?;
                let wd = io_scalar(io, "wd")?;
                let (loss, acc) = self.weight_phase(
                    state,
                    Some(&coeffs),
                    x,
                    y,
                    lr,
                    wd,
                    Some((teacher, mu)),
                )?;
                let mut m = Metrics::new();
                m.insert("loss".into(), Tensor::scalar_f32(loss));
                m.insert("acc".into(), Tensor::scalar_f32(acc));
                Ok(m)
            }
            "fp_eval" => self.eval_graph(state, None, io),
            "eval" => {
                let coeffs = Coeffs {
                    cw: self.coeff_rows(io_f32(io, "sel_w")?)?,
                    cx: self.coeff_rows(io_f32(io, "sel_x")?)?,
                };
                self.eval_graph(state, Some(&coeffs), io)
            }
            "fp_infer" => self.infer_graph(state, None, io),
            "infer" => {
                let coeffs = Coeffs {
                    cw: self.coeff_rows(io_f32(io, "sel_w")?)?,
                    cx: self.coeff_rows(io_f32(io, "sel_x")?)?,
                };
                self.infer_graph(state, Some(&coeffs), io)
            }
            "search_det" => self.search_graph(state, io, false),
            "search_sto" => self.search_graph(state, io, true),
            other => bail!(
                "native backend does not implement graph '{other}' \
                 (supported: init/fp_train/fp_eval/fp_infer/train/eval/infer/search_det/search_sto)"
            ),
        }?;
        Ok((metrics, t0.elapsed()))
    }
}
