//! Native-backend correctness: finite-difference verification of the
//! hand-written backward passes at the whole-network level, plus
//! step-graph semantic invariants that pin the Rust interpreter to
//! `python/compile/steps.py`.
//!
//! Gradient-check strategy: the FP path (no quantizers) is smooth
//! almost everywhere, so full-vector central differences against the
//! analytic gradient must agree to high cosine similarity (individual
//! coordinates may straddle a ReLU kink; vector-level metrics are
//! robust to that).  For the arch path, the branch coefficients enter
//! the aggregation *linearly* (their own quantize inputs don't move
//! with p), so dL/dr of the last block's conv is numerically checkable
//! despite the STE.

use ebs::coordinator::FlopsModel;
use ebs::native::graph::Coeffs;
use ebs::native::{quant, Grads, NativeNet, TapeArena};
use ebs::runtime::{metric_f32, Engine, StateVec, Tensor};
use ebs::util::Rng;

mod common;
use common::open_engine;

fn small_batch(engine: &Engine, batch: usize, rng: &mut Rng) -> (Vec<f32>, Vec<i32>) {
    let [h, w, c] = engine.manifest.image;
    let x: Vec<f32> = (0..batch * h * w * c).map(|_| rng.normal().abs()).collect();
    let y: Vec<i32> = (0..batch).map(|_| rng.below(engine.manifest.num_classes) as i32).collect();
    (x, y)
}

fn cosine(a: &[f32], b: &[f64]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(&x, &y)| x as f64 * y).sum();
    let na: f64 = a.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|&y| y * y).sum::<f64>().sqrt();
    dot / (na * nb).max(1e-30)
}

/// CE loss of an FP forward at the given state (batch statistics mode,
/// updates discarded) — the scalar function the FP grad-check probes.
fn fp_loss(
    net: &NativeNet,
    arena: &mut TapeArena,
    state: &StateVec,
    x: &[f32],
    y: &[i32],
    classes: usize,
) -> f64 {
    net.forward(state, None, x, y.len(), true, arena).unwrap();
    ebs::native::ops::cross_entropy(&arena.tape.logits, y, classes) as f64
}

/// Central differences at `indices` of one state leaf (strided subsets
/// keep the wall-clock sane on the bigger conv tensors — the cosine
/// over ~100 coordinates is signal enough).
#[allow(clippy::too_many_arguments)]
fn numeric_grad_at(
    net: &NativeNet,
    state: &StateVec,
    path: &str,
    indices: &[usize],
    x: &[f32],
    y: &[i32],
    classes: usize,
    eps: f32,
) -> Vec<f64> {
    let mut s = state.clone();
    let mut arena = TapeArena::new();
    let mut out = Vec::with_capacity(indices.len());
    for &j in indices {
        let orig = s.get(path).unwrap().as_f32().unwrap()[j];
        s.get_mut(path).unwrap().as_f32_mut().unwrap()[j] = orig + eps;
        let hi = fp_loss(net, &mut arena, &s, x, y, classes);
        s.get_mut(path).unwrap().as_f32_mut().unwrap()[j] = orig - eps;
        let lo = fp_loss(net, &mut arena, &s, x, y, classes);
        s.get_mut(path).unwrap().as_f32_mut().unwrap()[j] = orig;
        out.push((hi - lo) / (2.0 * eps as f64));
    }
    out
}

/// Up to `cap` indices covering a leaf with an even stride.
fn strided_indices(len: usize, cap: usize) -> Vec<usize> {
    let stride = len.div_ceil(cap).max(1);
    (0..len).step_by(stride).collect()
}

#[test]
fn fp_backward_matches_finite_differences() {
    let mut engine = open_engine("resnet8_tiny");
    let net = NativeNet::from_manifest(&engine.manifest).unwrap();
    let classes = engine.manifest.num_classes;
    let state = engine.init_state(3).unwrap();
    let mut rng = Rng::new(0xFD01);
    let (x, y) = small_batch(&engine, 4, &mut rng);

    // analytic: forward → dlogits = (softmax − onehot)/B → backward
    let mut arena = TapeArena::new();
    net.forward(&state, None, &x, y.len(), true, &mut arena).unwrap();
    let mut probs = Vec::new();
    ebs::native::ops::softmax_rows(&arena.tape.logits, y.len(), classes, &mut probs);
    let inv_b = 1.0 / y.len() as f32;
    let mut dlogits = vec![0f32; y.len() * classes];
    for (b, &lab) in y.iter().enumerate() {
        for c in 0..classes {
            let i = b * classes + c;
            dlogits[i] =
                (probs[i] - if lab as usize == c { 1.0 } else { 0.0 }) * inv_b;
        }
    }
    let mut grads = Grads::default();
    net.backward(&state, None, &mut arena, &dlogits, &mut grads).unwrap();

    // numeric checks across every layer family the backward touches:
    // conv stem, a mid-network qconv (FP mode here), BN affine, and the
    // classifier.  Large leaves are probed on an even-strided subset.
    for (path, min_cos) in [
        ("state/params/stem/w", 0.995),
        ("state/params/s1b0c1/w", 0.995),
        ("state/params/bn_s0b0c2/gamma", 0.995),
        ("state/params/bn_s2b0c1/beta", 0.995),
        ("state/params/fc/w", 0.999),
        ("state/params/fc/b", 0.999),
    ] {
        let analytic_full =
            grads.by_path.get(path).unwrap_or_else(|| panic!("no grad for {path}"));
        let idx = strided_indices(analytic_full.len(), 120);
        let analytic: Vec<f32> = idx.iter().map(|&j| analytic_full[j]).collect();
        let numeric = numeric_grad_at(&net, &state, path, &idx, &x, &y, classes, 1e-2);
        let cos = cosine(&analytic, &numeric);
        assert!(
            cos > min_cos,
            "{path}: analytic/numeric gradient cosine {cos:.4} < {min_cos}"
        );
        let na: f64 = analytic.iter().map(|&v| (v as f64).abs()).sum();
        let nn: f64 = numeric.iter().map(|v| v.abs()).sum();
        assert!(
            (na - nn).abs() < 0.15 * na.max(nn).max(1e-8),
            "{path}: gradient mass mismatch analytic {na:.5} vs numeric {nn:.5}"
        );
    }
}

#[test]
fn arch_gradient_of_last_conv_matches_finite_differences() {
    // dL/dr for the last block's c2 conv: its own quantizer inputs do
    // not move with the coefficients, so central differences are valid.
    let mut engine = open_engine("resnet8_tiny");
    let net = NativeNet::from_manifest(&engine.manifest).unwrap();
    let classes = engine.manifest.num_classes;
    let state = engine.init_state(7).unwrap();
    let mut rng = Rng::new(0xA12C);
    let (x, y) = small_batch(&engine, 4, &mut rng);

    let names = net.desc.qconv_names.clone();
    let li = names.iter().position(|n| n == "s2b0c2").unwrap();
    let n_bits = net.bits.len();

    // give the strengths non-trivial values so softmax isn't uniform
    let mut state = state;
    {
        let r = state.get_mut("state/arch/r/s2b0c2").unwrap().as_f32_mut().unwrap();
        r.copy_from_slice(&[0.3, -0.2, 0.5, 0.0, -0.4]);
        let s = state.get_mut("state/arch/s/s2b0c2").unwrap().as_f32_mut().unwrap();
        s.copy_from_slice(&[-0.1, 0.4, 0.2, -0.3, 0.0]);
    }

    let coeffs_of = |state: &StateVec| -> Coeffs {
        let mut cw = Vec::new();
        let mut cx = Vec::new();
        for name in &names {
            let r = state.get(&format!("state/arch/r/{name}")).unwrap().as_f32().unwrap();
            let s = state.get(&format!("state/arch/s/{name}")).unwrap().as_f32().unwrap();
            let (mut pw, mut px) = (Vec::new(), Vec::new());
            quant::softmax(r, &mut pw);
            quant::softmax(s, &mut px);
            cw.push(pw);
            cx.push(px);
        }
        Coeffs { cw, cx }
    };
    let loss_at = |state: &StateVec| -> f64 {
        let coeffs = coeffs_of(state);
        let mut arena = TapeArena::new();
        net.forward(state, Some(&coeffs), &x, y.len(), true, &mut arena).unwrap();
        ebs::native::ops::cross_entropy(&arena.tape.logits, &y, classes) as f64
    };

    // analytic dL/dr, dL/ds via backward + softmax VJP
    let coeffs = coeffs_of(&state);
    let mut arena = TapeArena::new();
    net.forward(&state, Some(&coeffs), &x, y.len(), true, &mut arena).unwrap();
    let mut probs = Vec::new();
    ebs::native::ops::softmax_rows(&arena.tape.logits, y.len(), classes, &mut probs);
    let inv_b = 1.0 / y.len() as f32;
    let mut dlogits = vec![0f32; y.len() * classes];
    for (b, &lab) in y.iter().enumerate() {
        for c in 0..classes {
            let i = b * classes + c;
            dlogits[i] = (probs[i] - if lab as usize == c { 1.0 } else { 0.0 }) * inv_b;
        }
    }
    let mut grads = Grads::default();
    net.backward(&state, Some(&coeffs), &mut arena, &dlogits, &mut grads).unwrap();
    let mut gr = vec![0f32; n_bits];
    let mut gs = vec![0f32; n_bits];
    quant::softmax_backward(&coeffs.cw[li], &grads.dcw[li], &mut gr);
    quant::softmax_backward(&coeffs.cx[li], &grads.dcx[li], &mut gs);

    // eps large enough that f32 forward rounding stays ≪ the loss
    // delta, small enough that curvature (and ReLU-kink crossings)
    // stay negligible.
    let eps = 2e-2f32;
    for (path, analytic) in [("state/arch/r/s2b0c2", &gr), ("state/arch/s/s2b0c2", &gs)] {
        let mut numeric = Vec::new();
        let mut s = state.clone();
        for j in 0..n_bits {
            let orig = s.get(path).unwrap().as_f32().unwrap()[j];
            s.get_mut(path).unwrap().as_f32_mut().unwrap()[j] = orig + eps;
            let hi = loss_at(&s);
            s.get_mut(path).unwrap().as_f32_mut().unwrap()[j] = orig - eps;
            let lo = loss_at(&s);
            s.get_mut(path).unwrap().as_f32_mut().unwrap()[j] = orig;
            numeric.push((hi - lo) / (2.0 * eps as f64));
        }
        let cos = cosine(analytic, &numeric);
        assert!(cos > 0.97, "{path}: cosine {cos:.4}, analytic {analytic:?} numeric {numeric:?}");
    }
}

#[test]
fn train_step_overfits_a_fixed_batch_under_onehot_selection() {
    let mut engine = open_engine("resnet8_tiny");
    let mut state = engine.init_state(1).unwrap();
    let mut rng = Rng::new(0x0F17);
    let b = engine.manifest.batch_size;
    let classes = engine.manifest.num_classes;
    let (x, y) = small_batch(&engine, b, &mut rng);
    let l = engine.manifest.num_qconvs();
    let n = engine.manifest.bits.len();
    let mut sel = vec![0f32; l * n];
    for row in 0..l {
        sel[row * n + n - 1] = 1.0; // 5-bit everywhere
    }
    let sel = Tensor::from_f32(&[l, n], sel);
    let zero_teacher = Tensor::from_f32(&[b, classes], vec![0.0; b * classes]);
    let mut losses = Vec::new();
    for _ in 0..12 {
        let io = vec![
            ("sel_w".to_string(), sel.clone()),
            ("sel_x".to_string(), sel.clone()),
            ("x".to_string(), Tensor::from_f32(&[b, 16, 16, 3], x.clone())),
            ("y".to_string(), Tensor::from_i32(&[b], y.clone())),
            ("teacher".to_string(), zero_teacher.clone()),
            ("lr".to_string(), Tensor::scalar_f32(0.05)),
            ("wd".to_string(), Tensor::scalar_f32(0.0)),
            ("mu".to_string(), Tensor::scalar_f32(0.0)),
        ];
        let m = engine.run("train", &mut state, &io).unwrap();
        losses.push(metric_f32(&m, "loss").unwrap());
    }
    assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
    assert!(
        losses[11] < losses[0],
        "quantized train step should overfit a fixed batch: {losses:?}"
    );
}

#[test]
fn search_penalty_drives_bits_down() {
    // With a tiny target and a large λ, repeated search steps must push
    // the argmax selection toward fewer bits — Eq. 9's penalty at work.
    let mut engine = open_engine("resnet8_tiny");
    let flops = FlopsModel::from_manifest(&engine.manifest).unwrap();
    let mut state = engine.init_state(2).unwrap();
    let mut rng = Rng::new(0xBEEF);
    let b = engine.manifest.batch_size;
    let start = ebs::coordinator::Selection::from_state(&state, &engine.manifest).unwrap();
    let (sw0, sx0) = start.mean_bits();

    let mut eflops_first = None;
    let mut eflops_last = 0.0;
    for _ in 0..30 {
        let (xt, yt) = small_batch(&engine, b, &mut rng);
        let (xv, yv) = small_batch(&engine, b, &mut rng);
        let io = vec![
            ("xt".to_string(), Tensor::from_f32(&[b, 16, 16, 3], xt)),
            ("yt".to_string(), Tensor::from_i32(&[b], yt)),
            ("xv".to_string(), Tensor::from_f32(&[b, 16, 16, 3], xv)),
            ("yv".to_string(), Tensor::from_i32(&[b], yv)),
            ("lr_w".to_string(), Tensor::scalar_f32(0.01)),
            ("lr_arch".to_string(), Tensor::scalar_f32(0.05)),
            ("wd".to_string(), Tensor::scalar_f32(0.0)),
            ("lam".to_string(), Tensor::scalar_f32(8.0)),
            ("target".to_string(), Tensor::scalar_f32(flops.uniform_mflops(1) as f32)),
        ];
        let m = engine.run("search_det", &mut state, &io).unwrap();
        let e = metric_f32(&m, "eflops").unwrap() as f64;
        eflops_first.get_or_insert(e);
        eflops_last = e;
    }
    let sel = ebs::coordinator::Selection::from_state(&state, &engine.manifest).unwrap();
    let (sw, sx) = sel.mean_bits();
    assert!(
        sw + sx < sw0 + sx0,
        "penalty should reduce mean bits: {sw0:.2}+{sx0:.2} → {sw:.2}+{sx:.2}"
    );
    assert!(
        eflops_last < eflops_first.unwrap(),
        "expected FLOPs should fall: {:?} → {eflops_last}",
        eflops_first
    );
}

#[test]
fn first_search_step_eflops_matches_uniform_coefficient_cost() {
    // Fresh state → zero strengths → uniform softmax → E[M]=E[K]=3 →
    // the eflops metric must equal the analytic Eq. 11 value.
    let mut engine = open_engine("resnet8_tiny");
    let flops = FlopsModel::from_manifest(&engine.manifest).unwrap();
    let mut state = engine.init_state(4).unwrap();
    let mut rng = Rng::new(0xE1F);
    let b = engine.manifest.batch_size;
    let (xt, yt) = small_batch(&engine, b, &mut rng);
    let (xv, yv) = small_batch(&engine, b, &mut rng);
    let io = vec![
        ("xt".to_string(), Tensor::from_f32(&[b, 16, 16, 3], xt)),
        ("yt".to_string(), Tensor::from_i32(&[b], yt)),
        ("xv".to_string(), Tensor::from_f32(&[b, 16, 16, 3], xv)),
        ("yv".to_string(), Tensor::from_i32(&[b], yv)),
        ("lr_w".to_string(), Tensor::scalar_f32(0.01)),
        ("lr_arch".to_string(), Tensor::scalar_f32(0.02)),
        ("wd".to_string(), Tensor::scalar_f32(5e-4)),
        ("lam".to_string(), Tensor::scalar_f32(0.5)),
        ("target".to_string(), Tensor::scalar_f32(1.0)),
    ];
    let m = engine.run("search_det", &mut state, &io).unwrap();
    let eflops = metric_f32(&m, "eflops").unwrap() as f64;
    let l = flops.num_layers();
    let n = flops.bits.len();
    let uniform = vec![1.0 / n as f32; l * n];
    let want = flops.expected_mflops(&uniform, &uniform);
    assert!(
        (eflops - want).abs() < 1e-4 * want,
        "first-step eflops {eflops} != analytic uniform-coefficient cost {want}"
    );
}

#[test]
fn fp_train_decays_alpha_through_momentum() {
    // steps.py applies sgd_momentum to α even in FP mode (zero grad +
    // weight decay) — a subtle semantic the native backend must keep.
    let mut engine = open_engine("resnet8_tiny");
    let mut state = engine.init_state(6).unwrap();
    let mut rng = Rng::new(0xA1FA);
    let b = engine.manifest.batch_size;
    let (x, y) = small_batch(&engine, b, &mut rng);
    let io = vec![
        ("x".to_string(), Tensor::from_f32(&[b, 16, 16, 3], x)),
        ("y".to_string(), Tensor::from_i32(&[b], y)),
        ("lr".to_string(), Tensor::scalar_f32(0.1)),
        ("wd".to_string(), Tensor::scalar_f32(0.1)),
    ];
    engine.run("fp_train", &mut state, &io).unwrap();
    let alpha = state.get("state/alphas/s0b0c1").unwrap().as_f32().unwrap()[0];
    // v = wd·α = 0.6; α' = 6 − 0.1·0.6 = 5.94
    assert!((alpha - 5.94).abs() < 1e-4, "α after decayed FP step: {alpha}");
    // BN running stats moved off their init
    let mean = state.get("state/bn/stem/mean").unwrap().as_f32().unwrap();
    assert!(mean.iter().any(|&m| m != 0.0), "BN running mean should update");
}
