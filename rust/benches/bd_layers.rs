//! Bench: Table 4 layer latencies (the paper's deployment experiment).
//! Thin wrapper over `report::table4` so `cargo bench` regenerates the
//! table directly.  `EBS_BENCH_REPS` controls the median window;
//! `EBS_BENCH_EXTENDED=1` adds the M·K linearity sweep (Table 4b).

use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let reps: usize =
        std::env::var("EBS_BENCH_REPS").ok().and_then(|s| s.parse().ok()).unwrap_or(5);
    let extended = std::env::var("EBS_BENCH_EXTENDED").map(|v| v == "1").unwrap_or(false);
    let out = PathBuf::from(
        std::env::var("EBS_BENCH_OUT").unwrap_or_else(|_| "runs/reports".into()),
    );
    ebs::report::table4::run(&out, reps, extended)
}
