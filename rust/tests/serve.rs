//! Serve-layer integration tests (DESIGN.md §13, §15): bit-identical
//! served predictions, graceful shutdown drain, admission control,
//! and the TCP front-end under concurrent load.
//!
//! The deterministic boundary behavior of the coalescer itself
//! (exactly-at-max_batch, never-split, oversized-alone) is pinned by
//! the unit tests in `serve::batcher`; these tests cover the threaded
//! end of the same contracts.  Gateway-tier behavior (multi-model
//! routing, hot swap, telemetry, protocol v2 errors over the wire)
//! lives in tests/serve_gateway.rs.

use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use ebs::bd::BdNetwork;
use ebs::serve::protocol::{self, Request, Response};
use ebs::serve::server::Server;
use ebs::serve::{no_loader, ServeCfg, ServeCore, ServeHandle, SubmitError};
use ebs::util::Rng;

fn test_cfg(workers: usize, max_batch: usize, max_wait_us: u64) -> ServeCfg {
    ServeCfg {
        addr: "127.0.0.1:0".into(),
        workers,
        max_batch,
        max_wait_us,
        queue_depth: 256,
        metrics_addr: String::new(),
    }
}

/// Shared image pool + the ground-truth predictions of a direct
/// `classify_batch` call on the whole pool.
fn pool(seed: u64, n: usize) -> (Vec<f32>, Vec<usize>, usize) {
    let net = BdNetwork::synthetic(seed);
    let img_sz = net.input_hw * net.input_hw * net.input_ch;
    let mut rng = Rng::new(seed ^ 0x1111);
    let xs: Vec<f32> = (0..n * img_sz).map(|_| rng.normal().abs()).collect();
    let direct = net.classify_batch(&xs, n);
    (xs, direct, img_sz)
}

/// Carve `n` images into requests of cycling sizes 1, 2, 3, ...
fn request_plan(n: usize) -> Vec<(usize, usize)> {
    let mut plan = Vec::new();
    let (mut off, mut k) = (0usize, 1usize);
    while off < n {
        let count = k.min(n - off);
        plan.push((off, count));
        off += count;
        k = if k == 3 { 1 } else { k + 1 };
    }
    plan
}

/// Served predictions must be bit-identical to a direct
/// `classify_batch` on the same inputs, at any worker count and under
/// concurrent submission (coalescing on).
#[test]
fn served_predictions_bit_identical_to_direct_classify_batch() {
    let n = 24;
    let (xs, direct, img_sz) = pool(7, n);
    for workers in [1usize, 3] {
        let handle = Arc::new(ServeHandle::start_synthetic(7, test_cfg(workers, 8, 2000)));
        let mut joins = Vec::new();
        for (off, count) in request_plan(n) {
            let h = Arc::clone(&handle);
            let req = xs[off * img_sz..(off + count) * img_sz].to_vec();
            let want = direct[off..off + count].to_vec();
            joins.push(std::thread::spawn(move || {
                // Empty model name = the sole resident model.
                let got = h.classify("", req, count).unwrap();
                assert_eq!(got, want, "request at offset {off} (count {count})");
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let core = Arc::clone(&handle.core);
        match Arc::try_unwrap(handle) {
            Ok(h) => h.shutdown(),
            Err(_) => panic!("all clients joined; handle must be unique"),
        }
        let stats = &core.stats;
        let images = stats.images.load(Ordering::Relaxed);
        let batch_max = stats.batch_images_max.load(Ordering::Relaxed);
        assert_eq!(images as usize, n, "workers={workers}");
        assert!(batch_max <= 8, "coalescer must respect max_batch (saw {batch_max})");
        // Per-model telemetry agrees with the global counters.
        let m = core.registry.resolve("default").unwrap();
        assert_eq!(m.stats.images.load(Ordering::Relaxed) as usize, n);
    }
}

/// Graceful shutdown: every admitted request is answered — including
/// ones still queued when shutdown begins — and later submissions are
/// cleanly rejected, never silently dropped.
#[test]
fn shutdown_answers_all_queued_requests_and_rejects_new_ones() {
    let n = 40;
    let (xs, direct, img_sz) = pool(11, n);
    let handle = ServeHandle::start_synthetic(11, test_cfg(1, 4, 0));
    let core = Arc::clone(&handle.core);
    let receivers: Vec<_> = (0..n)
        .map(|i| {
            core.submit("default", xs[i * img_sz..(i + 1) * img_sz].to_vec(), 1)
                .expect("queue_depth 256 admits the whole burst")
        })
        .collect();
    // Close with (most of) the burst still queued behind one worker.
    handle.shutdown();
    for (i, rx) in receivers.into_iter().enumerate() {
        let preds = rx.recv().expect("admitted request must be answered, not dropped");
        assert_eq!(preds, &direct[i..i + 1], "request {i}");
    }
    match core.submit("default", xs[..img_sz].to_vec(), 1) {
        Err(SubmitError::ShuttingDown) => {}
        other => panic!("post-shutdown submit must be rejected, got {other:?}"),
    }
    let admitted = core.stats.admitted.load(Ordering::Relaxed);
    let completed = core.stats.completed.load(Ordering::Relaxed);
    assert_eq!((admitted, completed), (n as u64, n as u64));
}

/// Admission control: with no workers draining, the bounded queue
/// rejects exactly the overflow — and hands rejections out
/// synchronously (backpressure, not buffering).
#[test]
fn bounded_queue_rejects_overflow_synchronously() {
    let mut cfg = test_cfg(1, 8, 0);
    cfg.queue_depth = 2;
    let core = ServeCore::new(cfg, no_loader());
    let resident = core.registry.publish_synthetic("m", 3);
    let img = vec![0.5f32; resident.image_size()];
    assert!(core.submit("m", img.clone(), 1).is_ok());
    assert!(core.submit("m", img.clone(), 1).is_ok());
    match core.submit("m", img.clone(), 1) {
        Err(SubmitError::Overloaded) => {}
        other => panic!("third submit must hit admission control, got {other:?}"),
    }
    assert_eq!(core.stats.rejected_full.load(Ordering::Relaxed), 1);
    // The rejection is attributed to the model it targeted, too.
    assert_eq!(resident.stats.rejected_full.load(Ordering::Relaxed), 1);
    // A submission to a model that is not resident is refused without
    // touching the queue.
    match core.submit("ghost", img, 1) {
        Err(SubmitError::UnknownModel) => {}
        other => panic!("unknown model must be refused, got {other:?}"),
    }
}

fn roundtrip(stream: &mut TcpStream, req: &Request) -> Response {
    use std::io::Write;
    stream.write_all(&protocol::encode_request(req)).unwrap();
    let payload = protocol::read_frame(stream).unwrap().expect("server hung up mid-request");
    protocol::decode_response(&payload).unwrap()
}

/// Full TCP stack: concurrent connections, pipelined mixed-size
/// requests, stats introspection, graceful shutdown, clean exit.
#[test]
fn tcp_server_serves_concurrent_load_and_shuts_down_cleanly() {
    let n = 24;
    let (xs, direct, img_sz) = pool(9, n);
    let core = ServeCore::new(test_cfg(2, 8, 500), no_loader());
    core.registry.publish_synthetic("default", 9);
    let server = Server::bind(core).unwrap();
    let addr = server.local_addr().unwrap();
    let server_join = std::thread::spawn(move || server.run());

    let xs = Arc::new(xs);
    let direct = Arc::new(direct);
    let mut clients = Vec::new();
    for t in 0..4usize {
        let (xs, direct) = (Arc::clone(&xs), Arc::clone(&direct));
        clients.push(std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            // Each client owns every 4th request of the shared plan.
            for (i, (off, count)) in request_plan(n).into_iter().enumerate() {
                if i % 4 != t {
                    continue;
                }
                let id = (t * 1000 + i) as u32;
                let req = Request::Classify {
                    id,
                    model: "default".into(),
                    count: count as u32,
                    images: xs[off * img_sz..(off + count) * img_sz].to_vec(),
                };
                match roundtrip(&mut stream, &req) {
                    Response::Classify { id: rid, labels } => {
                        assert_eq!(rid, id);
                        let want: Vec<u32> =
                            direct[off..off + count].iter().map(|&p| p as u32).collect();
                        assert_eq!(labels, want, "served ≠ direct at offset {off}");
                    }
                    other => panic!("unexpected response {other:?}"),
                }
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }

    // Control connection: bad geometry → error (session survives);
    // stats; shutdown.
    let mut ctl = TcpStream::connect(addr).unwrap();
    let bad = Request::Classify { id: 5, model: String::new(), count: 3, images: vec![0.0; 7] };
    match roundtrip(&mut ctl, &bad) {
        Response::Error { id, code, msg } => {
            assert_eq!((id, code), (5, protocol::ERR_BAD_REQUEST));
            assert!(msg.contains("image size"), "error must carry the cause: {msg}");
        }
        other => panic!("bad geometry must be rejected, got {other:?}"),
    }
    match roundtrip(&mut ctl, &Request::Stats { id: 6, model: String::new() }) {
        Response::Stats { id, json } => {
            assert_eq!(id, 6);
            assert!(json.contains("\"models\""), "stats must list residents: {json}");
            assert!(json.contains("\"input_hw\""), "stats must expose geometry: {json}");
            assert!(json.contains("\"batches\""), "stats must expose counters: {json}");
        }
        other => panic!("unexpected stats response {other:?}"),
    }
    match roundtrip(&mut ctl, &Request::Shutdown { id: 7 }) {
        Response::ShutdownAck { id } => assert_eq!(id, 7),
        other => panic!("unexpected shutdown response {other:?}"),
    }
    server_join.join().unwrap().unwrap();
    assert!(
        TcpStream::connect(addr).is_err(),
        "listener must be gone after a clean shutdown"
    );
}
