//! Fig. 3 regenerator: the aggregated quantization function of Eq. 6.
//!
//! Sweeps w ∈ [-2.5, 2.5] and dumps the EBS aggregated quantized value
//! for several strength settings — single precisions (step functions),
//! the uniform mixture r=[0,0] over B={2,3}, and the skewed mixture
//! r=[-1,1] — reproducing the paper's visualization that EBS interpolates
//! between candidate step functions during search.

use anyhow::Result;

use crate::quant::round_half_up;

use super::table_fmt::Table;

/// quantize_b on the already-normalized [0,1] value (Eq. 1c).
fn quantize_b(t: f32, bits: u32) -> f32 {
    let levels = ((1u32 << bits) - 1) as f32;
    round_half_up(t * levels) / levels
}

/// Eq. 6 aggregated weight quantization at softmax(r) coefficients over
/// candidate set `bits`, for a *population* of weights whose max |tanh|
/// is `max_tanh` (we use the sweep's own max, as in training).
fn ebs_value(w: f32, max_tanh: f32, bits: &[u32], r: &[f32]) -> f32 {
    let norm = w.tanh() / (2.0 * max_tanh) + 0.5;
    let mx = r.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = r.iter().map(|&x| (x - mx).exp()).collect();
    let z: f32 = exps.iter().sum();
    bits.iter()
        .zip(&exps)
        .map(|(&b, &e)| e / z * (2.0 * quantize_b(norm, b) - 1.0))
        .sum()
}

/// Dump the Fig. 3 curves to CSV.
pub fn run(out: &std::path::Path, points: usize) -> Result<()> {
    let mut table = Table::new(
        "Fig. 3 — aggregated quantization function (Eq. 6)",
        &[
            "w", "b2_only", "b3_only",
            "mix_b23_r00",  // r = [0, 0]  → 0.5·Ŵ² + 0.5·Ŵ³
            "mix_b23_rm1p1", // r = [-1, 1] → mostly 3-bit
            "mix_b15_r0",   // full candidate set, uniform strengths
        ],
    );
    let lim = 2.5f32;
    let max_tanh = lim.tanh();
    for i in 0..=points {
        let w = -lim + 2.0 * lim * i as f32 / points as f32;
        table.row(vec![
            format!("{w:.4}"),
            format!("{:.5}", ebs_value(w, max_tanh, &[2], &[0.0])),
            format!("{:.5}", ebs_value(w, max_tanh, &[3], &[0.0])),
            format!("{:.5}", ebs_value(w, max_tanh, &[2, 3], &[0.0, 0.0])),
            format!("{:.5}", ebs_value(w, max_tanh, &[2, 3], &[-1.0, 1.0])),
            format!("{:.5}", ebs_value(w, max_tanh, &[1, 2, 3, 4, 5], &[0.0; 5])),
        ]);
    }
    table.write(out, "fig3")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_mixture_has_finer_steps_than_either_branch() {
        // The r=[0,0] mixture over {2,3} must take strictly more distinct
        // values than the 3-bit step function alone (the paper's "larger
        // capacity" argument).
        let lim = 2.5f32;
        let max_tanh = lim.tanh();
        let distinct = |f: &dyn Fn(f32) -> f32| {
            let mut vals: Vec<i64> = (0..=2000)
                .map(|i| {
                    let w = -lim + 2.0 * lim * i as f32 / 2000.0;
                    (f(w) * 1e6).round() as i64
                })
                .collect();
            vals.sort();
            vals.dedup();
            vals.len()
        };
        let mix = distinct(&|w| ebs_value(w, max_tanh, &[2, 3], &[0.0, 0.0]));
        let b3 = distinct(&|w| ebs_value(w, max_tanh, &[3], &[0.0]));
        assert!(mix > b3, "mixture {mix} levels vs 3-bit {b3}");
    }

    #[test]
    fn skewed_mixture_approaches_dominant_branch() {
        let lim = 2.5f32;
        let max_tanh = lim.tanh();
        for i in 0..50 {
            let w = -lim + 2.0 * lim * i as f32 / 49.0;
            let skew = ebs_value(w, max_tanh, &[2, 3], &[-4.0, 4.0]);
            let b3 = ebs_value(w, max_tanh, &[3], &[0.0]);
            assert!((skew - b3).abs() < 0.02, "at w={w}: {skew} vs {b3}");
        }
    }
}
