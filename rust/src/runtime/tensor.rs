//! Host-side tensors — the coordinator's view of model state and batches.
//!
//! The CPU PJRT "device" shares host memory, so a plain `Vec`-backed
//! tensor plus a per-call `Literal` conversion is the whole story; the
//! conversion cost is one memcpy (measured in EXPERIMENTS.md §Perf).

use anyhow::{bail, Result};

/// Element type of a tensor (the manifests only emit these two).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            _ => bail!("unsupported dtype '{s}'"),
        }
    }
}

/// Dense host tensor.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn zeros(dtype: DType, shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        match dtype {
            DType::F32 => Tensor::F32 { shape: shape.to_vec(), data: vec![0.0; n] },
            DType::I32 => Tensor::I32 { shape: shape.to_vec(), data: vec![0; n] },
        }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::I32 { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } => DType::F32,
            Tensor::I32 { .. } => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn size_bytes(&self) -> usize {
        self.len() * 4
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Scalar extraction (any rank-0/single-element tensor).
    pub fn item_f32(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("item_f32 on tensor of {} elements", d.len());
        }
        Ok(d[0])
    }

    /// Convert to an XLA literal for execution.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
            Tensor::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        };
        Ok(lit)
    }

    /// Read back from an XLA literal, checking shape/dtype against a spec.
    pub fn from_literal(lit: &xla::Literal, dtype: DType, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        let t = match dtype {
            DType::F32 => {
                let v = lit.to_vec::<f32>()?;
                if v.len() != n {
                    bail!("literal has {} elements, spec wants {n}", v.len());
                }
                Tensor::F32 { shape: shape.to_vec(), data: v }
            }
            DType::I32 => {
                let v = lit.to_vec::<i32>()?;
                if v.len() != n {
                    bail!("literal has {} elements, spec wants {n}", v.len());
                }
                Tensor::I32 { shape: shape.to_vec(), data: v }
            }
        };
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_bytes() {
        let t = Tensor::zeros(DType::F32, &[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert_eq!(t.size_bytes(), 96);
    }

    #[test]
    fn scalar_roundtrip_shape() {
        let t = Tensor::scalar_f32(1.5);
        assert_eq!(t.shape(), &[] as &[usize]);
        assert_eq!(t.item_f32().unwrap(), 1.5);
    }
}
