//! Fig. 7 regenerator: per-layer bitwidth distribution of a searched
//! selection — weight bits vs activation bits per quantized conv, plus
//! the Fig. 7 takeaway check (weights skew lower than activations in
//! least-FLOPs searches).

use std::path::Path;

use anyhow::Result;

use crate::coordinator::Selection;
use crate::runtime::Manifest;

use super::table_fmt::Table;

/// Render a saved selection against its model manifest.
pub fn run(manifest: &Manifest, selection_path: &Path, out: &Path) -> Result<()> {
    let sel = Selection::load(selection_path)?;
    anyhow::ensure!(
        sel.w_bits.len() == manifest.num_qconvs(),
        "selection has {} layers; model {} has {}",
        sel.w_bits.len(),
        manifest.model,
        manifest.num_qconvs()
    );
    let mut table = Table::new(
        &format!("Fig. 7 — precision distribution, {}", manifest.model),
        &["Layer", "MACs (M)", "W bits", "A bits", "W bar", "A bar"],
    );
    for (i, name) in manifest.qconv_layers.iter().enumerate() {
        let macs = manifest.qconv_macs[name] as f64 / 1e6;
        table.row(vec![
            name.clone(),
            format!("{macs:.3}"),
            sel.w_bits[i].to_string(),
            sel.x_bits[i].to_string(),
            "#".repeat(sel.w_bits[i] as usize),
            "*".repeat(sel.x_bits[i] as usize),
        ]);
    }
    let (mw, mx) = sel.mean_bits();
    table.row(vec![
        "(mean)".into(),
        "-".into(),
        format!("{mw:.2}"),
        format!("{mx:.2}"),
        String::new(),
        String::new(),
    ]);
    table.write(out, "fig7")?;
    println!(
        "[fig7] mean weight bits {mw:.2} vs activation bits {mx:.2} — paper expects w ≤ a \
         for least-FLOPs searches"
    );
    Ok(())
}
