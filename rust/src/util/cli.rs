//! Tiny CLI argument parser (offline substitute for `clap`; DESIGN.md §3).
//!
//! Grammar: `ebs <subcommand> [--flag value]... [--switch]... [positional]...`

use std::collections::HashMap;

use anyhow::{Context, Result};

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub flags: HashMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()` given the set of boolean switch names
    /// (flags that take no value).
    pub fn parse(raw: impl Iterator<Item = String>, switch_names: &[&str]) -> Result<Args> {
        let mut args = Args::default();
        let mut raw = raw.skip(1).peekable(); // skip argv[0]
        if let Some(first) = raw.peek() {
            if !first.starts_with("--") {
                args.subcommand = raw.next().unwrap();
            }
        }
        while let Some(a) = raw.next() {
            if let Some(name) = a.strip_prefix("--") {
                if switch_names.contains(&name) {
                    args.switches.push(name.to_string());
                } else {
                    let v = raw
                        .next()
                        .with_context(|| format!("flag --{name} needs a value"))?;
                    args.flags.insert(name.to_string(), v);
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    pub fn req_flag(&self, name: &str) -> Result<&str> {
        self.flag(name)
            .with_context(|| format!("required flag --{name} missing"))
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            Some(v) => v.parse().with_context(|| format!("--{name} must be an integer")),
            None => Ok(default),
        }
    }

    pub fn f64_flag(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            Some(v) => v.parse().with_context(|| format!("--{name} must be a number")),
            None => Ok(default),
        }
    }

    pub fn has_switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    /// Error out on an unknown subcommand, listing valid ones.
    pub fn unknown_subcommand(&self, valid: &[&str]) -> anyhow::Error {
        let cmd = &self.subcommand;
        anyhow::anyhow!("unknown subcommand '{cmd}'; expected one of: {}", valid.join(", "))
    }
}

/// Scan raw process argv for `--flag value` (as passed through by
/// `cargo bench -- --flag value`); `default` is used when the flag is
/// present but has no value (last token, or followed by another
/// `--flag`).  Returns `None` when the flag is absent.
pub fn argv_value_flag(flag: &str, default: &str) -> Option<String> {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter().position(|a| a == flag).map(|i| {
        match argv.get(i + 1) {
            Some(v) if !v.starts_with("--") => v.clone(),
            _ => default.to_string(),
        }
    })
}

/// `a,b,c` → vec of trimmed non-empty strings.
pub fn split_csv(s: &str) -> Vec<String> {
    s.split(',')
        .map(|x| x.trim().to_string())
        .filter(|x| !x.is_empty())
        .collect()
}

/// Parse `1,2,3`-style numeric lists.
pub fn parse_csv_f64(s: &str) -> Result<Vec<f64>> {
    split_csv(s)
        .into_iter()
        .map(|x| {
            x.parse::<f64>()
                .with_context(|| format!("'{x}' is not a number"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(
            std::iter::once("ebs".to_string()).chain(v.iter().map(|s| s.to_string())),
            &["verbose", "dnas"],
        )
        .unwrap()
    }

    #[test]
    fn subcommand_flags_switches() {
        let a = args(&["search", "--config", "c.toml", "--verbose", "extra"]);
        assert_eq!(a.subcommand, "search");
        assert_eq!(a.flag("config"), Some("c.toml"));
        assert!(a.has_switch("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn missing_value_errors() {
        let r = Args::parse(
            ["ebs", "run", "--config"].iter().map(|s| s.to_string()),
            &[],
        );
        assert!(r.is_err());
    }

    #[test]
    fn csv_parsing() {
        assert_eq!(split_csv("a, b,,c"), vec!["a", "b", "c"]);
        assert_eq!(parse_csv_f64("1, 2.5").unwrap(), vec![1.0, 2.5]);
    }
}
