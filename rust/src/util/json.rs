//! Minimal JSON parser (offline substitute for serde_json; DESIGN.md §3).
//!
//! Parses the artifact manifests emitted by `python/compile/aot.py` and
//! serializes report/checkpoint documents.  Supports the full JSON value
//! grammar (objects preserve key order); numbers are f64, which is exact
//! for every integer the manifests contain (< 2^53).

use std::collections::HashMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Mandatory object field.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_f64()? as u64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Ok(v),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Convenience: object → HashMap view.
    pub fn obj_map(&self) -> Result<HashMap<&str, &Json>> {
        Ok(self.as_obj()?.iter().map(|(k, v)| (k.as_str(), v)).collect())
    }

    /// Serialize back to compact JSON text.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Write a bench perf document in the DESIGN.md §9 schema — envelope
/// `{bench, reps, threads, tile_co, tile_n, rows}` — creating parent
/// directories as needed.  Shared by `benches/bd_gemm.rs` and
/// `report::table4` so the schema lives in one place.
pub fn write_bench_json(
    path: &std::path::Path,
    bench: &str,
    reps: usize,
    threads: usize,
    tiles: (usize, usize),
    rows: Vec<Json>,
) -> Result<()> {
    write_bench_json_with(path, bench, reps, threads, tiles, Vec::new(), rows)
}

/// [`write_bench_json`] with extra envelope fields appended after the
/// standard ones (e.g. `kernel_tier` for the SIMD-dispatched benches).
/// Envelope additions are safe for `ci/compare_bench.py`, whose row
/// identity is computed from row fields only.
pub fn write_bench_json_with(
    path: &std::path::Path,
    bench: &str,
    reps: usize,
    threads: usize,
    tiles: (usize, usize),
    extra: Vec<(String, Json)>,
    rows: Vec<Json>,
) -> Result<()> {
    let mut fields = vec![
        ("bench".into(), Json::Str(bench.to_string())),
        ("reps".into(), Json::Num(reps as f64)),
        ("threads".into(), Json::Num(threads as f64)),
        ("tile_co".into(), Json::Num(tiles.0 as f64)),
        ("tile_n".into(), Json::Num(tiles.1 as f64)),
    ];
    fields.extend(extra);
    fields.push(("rows".into(), Json::Arr(rows)));
    let doc = Json::Obj(fields);
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, doc.to_string())?;
    Ok(())
}

/// Nesting cap: `value()` recurses per `[`/`{` level, so unbounded
/// depth lets a hostile document (`[[[[…`) overflow the stack.  Real
/// manifests/reports nest a handful of levels; 128 is far above any
/// legitimate document while keeping worst-case stack use trivial.
pub const MAX_DEPTH: usize = 128;

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0, depth: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        bail!("trailing characters at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, got '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            c @ (b'{' | b'[') => {
                if self.depth >= MAX_DEPTH {
                    bail!("JSON nested deeper than {MAX_DEPTH} levels at byte {}", self.i);
                }
                self.depth += 1;
                let v = if c == b'{' { self.object() } else { self.array() };
                self.depth -= 1;
                v
            }
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            kvs.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(kvs));
                }
                c => bail!("expected ',' or '}}' at byte {}, got '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, got '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        // A lead byte whose sequence runs past the end of
                        // the document must error, not slice out of bounds.
                        let chunk = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| anyhow!("truncated UTF-8 sequence at byte {start}"))?;
                        s.push_str(std::str::from_utf8(chunk)?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = parse(r#"{"a": [1, 2.5, -3e2], "b": "x\ny", "c": true, "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "x\ny");
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn nested_and_empty() {
        let v = parse(r#"{"o":{},"a":[],"n":[[1],[2,[3]]]}"#).unwrap();
        assert_eq!(v.get("o").unwrap().as_obj().unwrap().len(), 0);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{} x").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse(r#""λ→Ŵ""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "λ→Ŵ");
    }

    /// Fuzz regression: unbounded `[[[[…` nesting used to recurse until
    /// the stack overflowed; the depth cap turns it into a typed error.
    #[test]
    fn pathological_nesting_is_rejected_not_stack_overflowed() {
        let deep = "[".repeat(MAX_DEPTH + 10);
        let err = parse(&deep).unwrap_err().to_string();
        assert!(err.contains("nested deeper"), "depth cap must name itself: {err}");
        assert!(parse(&"{\"k\":[".repeat(MAX_DEPTH)).is_err());
        // documents at sane depth still parse
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH - 1), "]".repeat(MAX_DEPTH - 1));
        assert!(parse(&ok).is_ok(), "depth just under the cap must stay valid");
    }
}
