//! Bitwidth selection (paper Eq. 4): the discrete per-layer (M, K)
//! assignment extracted from learned strengths, plus the one-hot
//! coefficient encoding fed back into the retrain/eval/infer graphs.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::{Manifest, StateVec, Tensor};
use crate::util::json::{parse, Json};
use crate::util::Rng;

use super::flops::FlopsModel;

/// Per-layer bitwidths for weights and activations (manifest qconv order).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Selection {
    pub w_bits: Vec<u32>,
    pub x_bits: Vec<u32>,
}

/// First-max argmax: index of the first element strictly greater than
/// everything before it that is never beaten later — i.e. the serial
/// strict-`>` scan the shared kernel layer pins (DESIGN.md §12).  NaN
/// entries never win (NaN loses every `>` comparison) and an all-NaN
/// (or empty) slice falls back to index 0, matching
/// [`crate::kernels::par_max_abs`]'s empty-input convention.
pub fn first_max_index(v: &[f32]) -> usize {
    let mut best = f32::NEG_INFINITY;
    let mut idx = 0usize;
    let mut found = false;
    for (i, &x) in v.iter().enumerate() {
        if !found && !x.is_nan() {
            best = x;
            idx = i;
            found = true;
        } else if x > best {
            best = x;
            idx = i;
        }
    }
    idx
}

impl Selection {
    /// Uniform-precision selection (baseline rows of Tables 1/2).
    pub fn uniform(w: u32, x: u32, layers: usize) -> Selection {
        Selection { w_bits: vec![w; layers], x_bits: vec![x; layers] }
    }

    /// Eq. 4: argmax over the learned strengths in a search state.
    ///
    /// Deterministic by the same convention as the native quant
    /// kernels ([`crate::kernels::par_max_abs`]): a strict-`>`
    /// left-to-right scan, so ties resolve to the *first* (lowest-bit)
    /// candidate and NaN strengths are skipped instead of panicking
    /// (NaN never wins a `>` comparison).  The old `max_by` +
    /// `partial_cmp().unwrap()` kept the *last* max and panicked on
    /// NaN — same-seed replays could disagree with the kernel-side
    /// argmax on tied strengths.
    pub fn from_state(state: &StateVec, manifest: &Manifest) -> Result<Selection> {
        let argmax_bits = |prefix: &str| -> Result<Vec<u32>> {
            manifest
                .qconv_layers
                .iter()
                .map(|name| {
                    let t = state.get(&format!("state/arch/{prefix}/{name}"))?;
                    let v = t.as_f32()?;
                    if v.len() != manifest.bits.len() {
                        bail!(
                            "strength vector for {name} has {} entries, {} candidates",
                            v.len(),
                            manifest.bits.len()
                        );
                    }
                    Ok(manifest.bits[first_max_index(v)])
                })
                .collect()
        };
        Ok(Selection { w_bits: argmax_bits("r")?, x_bits: argmax_bits("s")? })
    }

    /// Random-search baseline: sample uniformly until the exact cost
    /// lands within ±`tol` (relative) of `target_mflops` (paper §5.1
    /// keeps only QNNs whose FLOPs are in the target range).
    pub fn random_within(
        rng: &mut Rng,
        flops: &FlopsModel,
        target_mflops: f64,
        tol: f64,
        max_tries: usize,
    ) -> Result<Selection> {
        let l = flops.num_layers();
        for _ in 0..max_tries {
            let w: Vec<u32> = (0..l).map(|_| flops.bits[rng.below(flops.bits.len())]).collect();
            let x: Vec<u32> = (0..l).map(|_| flops.bits[rng.below(flops.bits.len())]).collect();
            let sel = Selection { w_bits: w, x_bits: x };
            let mf = flops.exact_mflops(&sel.w_bits, &sel.x_bits);
            if (mf - target_mflops).abs() / target_mflops <= tol {
                return Ok(sel);
            }
        }
        bail!(
            "no random selection hit {target_mflops:.2} MFLOPs (±{:.0}%) in {max_tries} tries",
            tol * 100.0
        )
    }

    /// One-hot (L, N) coefficient tensors for the train/eval/infer graphs.
    pub fn to_onehot(&self, manifest: &Manifest) -> Result<(Tensor, Tensor)> {
        let n = manifest.bits.len();
        let l = self.w_bits.len();
        if l != manifest.num_qconvs() {
            bail!("selection has {l} layers, model has {}", manifest.num_qconvs());
        }
        let encode = |bits: &[u32]| -> Result<Tensor> {
            let mut data = vec![0f32; l * n];
            for (i, &b) in bits.iter().enumerate() {
                let idx = manifest
                    .bits
                    .iter()
                    .position(|&c| c == b)
                    .with_context(|| format!("bitwidth {b} not a candidate"))?;
                data[i * n + idx] = 1.0;
            }
            Ok(Tensor::from_f32(&[l, n], data))
        };
        Ok((encode(&self.w_bits)?, encode(&self.x_bits)?))
    }

    /// Average bitwidths (Fig. 7 commentary: weights skew lower than acts).
    pub fn mean_bits(&self) -> (f64, f64) {
        let mw = self.w_bits.iter().map(|&b| b as f64).sum::<f64>() / self.w_bits.len() as f64;
        let mx = self.x_bits.iter().map(|&b| b as f64).sum::<f64>() / self.x_bits.len() as f64;
        (mw, mx)
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            (
                "w_bits".into(),
                Json::Arr(self.w_bits.iter().map(|&b| Json::Num(b as f64)).collect()),
            ),
            (
                "x_bits".into(),
                Json::Arr(self.x_bits.iter().map(|&b| Json::Num(b as f64)).collect()),
            ),
        ])
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Selection> {
        let j = parse(&std::fs::read_to_string(path)?)
            .with_context(|| format!("parsing selection {}", path.display()))?;
        let bits = |key: &str| -> Result<Vec<u32>> {
            j.req(key)?
                .as_arr()?
                .iter()
                .map(|v| Ok(v.as_usize()? as u32))
                .collect()
        };
        Ok(Selection { w_bits: bits("w_bits")?, x_bits: bits("x_bits")? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::flops::FlopsModel;

    fn toy_flops() -> FlopsModel {
        FlopsModel {
            fp_macs: 100_000,
            qconv_macs: (0..6).map(|i| (format!("l{i}"), 1_000_000u64)).collect(),
            bits: vec![1, 2, 3, 4, 5],
            fp32_mflops: 6.1,
        }
    }

    #[test]
    fn random_search_respects_target_window() {
        let f = toy_flops();
        let target = f.uniform_mflops(3);
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let s = Selection::random_within(&mut rng, &f, target, 0.1, 10_000).unwrap();
            let mf = f.exact_mflops(&s.w_bits, &s.x_bits);
            assert!((mf - target).abs() / target <= 0.1);
        }
    }

    /// Bail path: an unreachable target must produce the corrected
    /// human-readable message — a *percentage*, not the old malformed
    /// `±{tol:.0?}` debug-format that printed the raw fraction.
    #[test]
    fn random_search_bails_with_percentage_tolerance() {
        let f = toy_flops();
        let mut rng = Rng::new(2);
        // fp32 cost alone exceeds any quantized config by orders of
        // magnitude below this target, so no sample can land ±10%.
        let err = Selection::random_within(&mut rng, &f, 1e12, 0.1, 50).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("±10%"), "tolerance must render as a percentage: {msg}");
        assert!(msg.contains("in 50 tries"), "try budget must be reported: {msg}");
        assert!(!msg.contains("±0.1"), "old debug-format fraction must be gone: {msg}");
    }

    /// Eq. 4 argmax determinism: ties resolve to the first (lowest-bit)
    /// candidate — matching the chunk-order-stable kernel argmax — and
    /// NaN strengths are skipped, not panicked on.
    #[test]
    fn first_max_index_is_first_max_and_nan_safe() {
        assert_eq!(first_max_index(&[0.1, 0.5, 0.5, 0.2]), 1, "tie keeps the first max");
        assert_eq!(first_max_index(&[0.7, 0.1, 0.7]), 0);
        assert_eq!(first_max_index(&[0.3, 0.9, 0.1]), 1);
        assert_eq!(first_max_index(&[f32::NAN, 0.2, 0.9]), 2, "NaN never wins");
        assert_eq!(first_max_index(&[0.4, f32::NAN, 0.4]), 0, "NaN between ties is skipped");
        assert_eq!(
            first_max_index(&[f32::NEG_INFINITY, f32::NEG_INFINITY]),
            0,
            "degenerate -inf tie keeps the first"
        );
        assert_eq!(first_max_index(&[f32::NAN, f32::NAN]), 0, "all-NaN falls back to index 0");
        assert_eq!(first_max_index(&[]), 0);
    }

    /// End-to-end: a search state with tied and NaN strengths yields a
    /// deterministic first-max selection instead of a panic or a
    /// last-max pick.
    #[test]
    fn from_state_selects_first_max_and_survives_nan() {
        let mut engine = crate::runtime::Engine::native("resnet8_tiny").unwrap();
        let manifest = engine.manifest.clone();
        let mut state = engine.init_state(3).unwrap();
        let n = manifest.bits.len();
        let first = manifest.qconv_layers[0].clone();
        {
            let r = state.get_mut(&format!("state/arch/r/{first}")).unwrap().as_f32_mut().unwrap();
            r.fill(0.25); // exact all-way tie → first candidate
        }
        {
            let s = state.get_mut(&format!("state/arch/s/{first}")).unwrap().as_f32_mut().unwrap();
            s.fill(0.0);
            s[0] = f32::NAN; // poisoned leader slot → skipped
            s[n - 1] = 1.0;
        }
        let sel = Selection::from_state(&state, &manifest).unwrap();
        assert_eq!(sel.w_bits[0], manifest.bits[0], "tied strengths keep the first candidate");
        assert_eq!(sel.x_bits[0], manifest.bits[n - 1], "NaN is skipped, real max wins");
    }

    #[test]
    fn mean_bits() {
        let s = Selection { w_bits: vec![1, 2, 3], x_bits: vec![4, 4, 4] };
        let (mw, mx) = s.mean_bits();
        assert!((mw - 2.0).abs() < 1e-9);
        assert!((mx - 4.0).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip() {
        let s = Selection { w_bits: vec![1, 5], x_bits: vec![2, 3] };
        let tmp = std::env::temp_dir().join("ebs_sel_test.json");
        s.save(&tmp).unwrap();
        assert_eq!(Selection::load(&tmp).unwrap(), s);
        std::fs::remove_file(&tmp).ok();
    }
}
