//! Bench: search-step efficiency (paper Table 3).
//!
//! Times N iterations of the EBS `search_det` graph vs the DNAS
//! supernet `dnas_search` graph (N weight copies, N² convs) on the same
//! model and random data, and reports wall-clock + peak RSS + the
//! analytic weight-copy memory model.  `cargo bench --bench search_step`.
//!
//! Env knobs: EBS_BENCH_MODEL (default resnet8_tiny), EBS_BENCH_ITERS.

use std::path::PathBuf;

use ebs::baselines::dnas::{run_dnas_steps, weight_copy_bytes};
use ebs::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let model = std::env::var("EBS_BENCH_MODEL").unwrap_or_else(|_| "resnet8_tiny".into());
    let iters: usize =
        std::env::var("EBS_BENCH_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(10);
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(&model);
    if !dir.join("manifest.json").exists() && ebs::native::lookup(&model).is_none() {
        eprintln!(
            "[bench:search_step] artifacts for {model} missing and model not in the \
             native registry — run `make artifacts`; skipping"
        );
        return Ok(());
    }
    // auto: PJRT artifacts when present, otherwise the native backend
    let mut engine = Engine::open(&dir)?;
    eprintln!("[bench:search_step] backend: {}", engine.backend_name());
    let n_bits = engine.manifest.bits.len();
    println!(
        "# Table 3 bench — model={model}, {iters} iterations, batch={}",
        engine.manifest.batch_size
    );

    // EBS
    let mut state = engine.init_state(1)?;
    let ebs_cost = run_dnas_steps(&mut engine, "search_det", &mut state, iters, 7)?;
    let (one_copy, n_copies) = weight_copy_bytes(&engine, n_bits);
    println!(
        "EBS    : {:>8.2}s for {iters} iters ({:.3}s/iter)  peak_rss={:.2} GB  state={:.1} MB  weight_copies={:.2} MB",
        ebs_cost.total_seconds,
        ebs_cost.total_seconds / iters as f64,
        ebs_cost.peak_rss_bytes as f64 / 1e9,
        ebs_cost.state_bytes as f64 / 1e6,
        one_copy as f64 / 1e6,
    );

    // DNAS (only exported for models built with --dnas)
    if engine.manifest.graphs.contains_key("dnas_search") {
        let mut dstate = engine.init_dnas_state(1)?;
        let dnas_cost = run_dnas_steps(&mut engine, "dnas_search", &mut dstate, iters, 7)?;
        println!(
            "DNAS   : {:>8.2}s for {iters} iters ({:.3}s/iter)  peak_rss={:.2} GB  state={:.1} MB  weight_copies={:.2} MB",
            dnas_cost.total_seconds,
            dnas_cost.total_seconds / iters as f64,
            dnas_cost.peak_rss_bytes as f64 / 1e9,
            dnas_cost.state_bytes as f64 / 1e6,
            n_copies as f64 / 1e6,
        );
        println!(
            "ratio  : time {:.1}x, weight-copy memory {:.1}x (paper: O(N²)/O(N) vs O(1)/O(1))",
            dnas_cost.total_seconds / ebs_cost.total_seconds,
            n_copies as f64 / one_copy as f64,
        );
    } else {
        println!("DNAS   : artifacts not exported for {model} (aot.py --dnas); EBS-only run");
    }
    Ok(())
}
