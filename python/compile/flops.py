"""FLOPs cost model (paper Eq. 2 / 11).

Calibration (DESIGN.md §7.6): fitting the paper's own tables gives

    cost = Σ_fp-layers MACs  +  Σ_qconv MACs · (M·K) / 64

(e.g. ResNet-18 W1-A3: 3/64·quantMACs + stem = 207M vs the paper's
206M).  The same model is implemented in ``rust/src/coordinator/flops.rs``
for selection-time accounting; the manifest carries this module's MAC
table so a Rust unit test can assert parity.

Eq. 11's *expected* FLOPs replaces the discrete (M, K) with the branch
expectations E[M] = Σ f(r)_i·b_i and E[K] = Σ f(s)_j·b_j, which keeps the
penalty differentiable w.r.t. the strengths.
"""

from __future__ import annotations

from typing import Dict, Sequence

import jax.numpy as jnp

from .model import ModelCfg, conv_inventory

MIXED_DIVISOR = 64.0  # (M·K)/64 — calibrated against the paper's tables


def fp_macs(cfg: ModelCfg) -> int:
    """MACs of the always-full-precision layers (stem + classifier)."""
    return sum(c.macs for c in conv_inventory(cfg) if c.kind != "qconv")


def qconv_macs(cfg: ModelCfg) -> Dict[str, int]:
    """MACs per quantized conv, keyed by layer name."""
    return {c.name: c.macs for c in conv_inventory(cfg) if c.kind == "qconv"}


def expected_mflops(
    cfg: ModelCfg,
    coeffs_w: Dict[str, jnp.ndarray],
    coeffs_x: Dict[str, jnp.ndarray],
) -> jnp.ndarray:
    """Eq. 11: E[FLOPs] in MFLOPs, differentiable w.r.t. the coefficients.

    Works for softmax, Gumbel-softmax, and one-hot coefficient vectors
    (the latter reduces to the exact cost of a selection).
    """
    bits_vec = jnp.array(cfg.bits, jnp.float32)
    total = jnp.asarray(float(fp_macs(cfg)), jnp.float32)
    for name, macs in qconv_macs(cfg).items():
        e_m = jnp.sum(coeffs_w[name] * bits_vec)
        e_k = jnp.sum(coeffs_x[name] * bits_vec)
        total = total + float(macs) * e_m * e_k / MIXED_DIVISOR
    return total / 1e6


def uniform_mflops(cfg: ModelCfg, w_bits: int, x_bits: int) -> float:
    """Exact cost of a uniform-precision QNN (Table 1/2 baseline rows)."""
    q = sum(qconv_macs(cfg).values())
    return (fp_macs(cfg) + q * w_bits * x_bits / MIXED_DIVISOR) / 1e6


def full_precision_mflops(cfg: ModelCfg) -> float:
    """Cost of the FP32 network (the "1.0×" row)."""
    return sum(c.macs for c in conv_inventory(cfg)) / 1e6
