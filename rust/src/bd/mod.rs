//! Binary Decomposition deployment engine (paper §4.3, Eq. 12-14).
//!
//! Mixed precision (M-bit × K-bit) convolution on generic CPUs with no
//! special-hardware support: integer codes are expanded into bitplanes,
//! multiplied as binary matrices with AND+POPCNT, and recombined with
//! the stride-(M,K) powers-of-two kernel of Eq. 14.  Correctness chain
//! (DESIGN.md §7.4): `gemm` vs naive integer matmul (unit + property
//! tests) → `layer` vs fake-quantized float conv → `network` vs the
//! HLO `infer` artifact (integration test).

pub mod bitplane;
pub mod gemm;
pub mod im2col;
pub mod layer;
pub mod network;
pub mod reference;

pub use bitplane::{pack_cols, pack_rows, BitMatrix};
pub use layer::{BdConvLayer, BdMode};
pub use network::BdNetwork;
