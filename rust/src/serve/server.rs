//! Serve front-end (DESIGN.md §13, §15): a TCP accept loop (or a
//! single stdin/stdout session) feeding the queue → micro-batcher →
//! worker pipeline, an optional HTTP metrics listener, and graceful
//! drain on shutdown.
//!
//! Threading: one reader thread per connection decodes frames and
//! submits classify requests; completions write the response frame
//! straight from the worker under the connection's write mutex (no
//! per-connection writer thread — a slow client briefly blocks one
//! worker, acceptable at this scale and it makes the drain trivially
//! correct: once the pool joins, every response has been written).
//!
//! Error reporting: a malformed or wrong-version frame gets an error
//! frame carrying the typed cause (`ERR_MALFORMED_FRAME` /
//! `ERR_UNSUPPORTED_VERSION` + message) before the session closes —
//! clients can always distinguish a torn frame from bad geometry
//! (`ERR_BAD_REQUEST`, session stays open) from an unknown model
//! (`ERR_UNKNOWN_MODEL`).
//!
//! Shutdown protocol: on a shutdown request the session acks, closes
//! the queue (no new admissions anywhere — concurrent submissions get
//! `ERR_SHUTTING_DOWN` frames), and flips the accept loop's flag; the
//! front-end then joins the worker pool, which by the queue's
//! drain-on-close contract answers every admitted request first.
//! EOF on stdin (stdio mode) triggers the same drain.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::protocol::{
    self, FrameError, Request, Response, ERR_BAD_REQUEST, ERR_LOAD_FAILED, ERR_OVERLOADED,
    ERR_SHUTTING_DOWN, ERR_UNKNOWN_MODEL,
};
use super::{ServeCfg, ServeCore, ServeHandle, SubmitError};

/// A bound-but-not-yet-serving TCP front-end (bind is separate from
/// run so callers can learn the ephemeral ports before serving).
pub struct Server {
    listener: TcpListener,
    metrics_listener: Option<TcpListener>,
    handle: ServeHandle,
    shutdown: Arc<AtomicBool>,
}

impl Server {
    /// Bind `cfg.addr` (and `cfg.metrics_addr` when set) and spawn the
    /// worker pool over the prepared core; serving starts at
    /// [`Server::run`].
    pub fn bind(core: Arc<ServeCore>) -> Result<Server> {
        let cfg: ServeCfg = core.cfg.clone();
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding serve address {}", cfg.addr))?;
        let metrics_listener = if cfg.metrics_addr.is_empty() {
            None
        } else {
            Some(
                TcpListener::bind(&cfg.metrics_addr)
                    .with_context(|| format!("binding metrics address {}", cfg.metrics_addr))?,
            )
        };
        let handle = ServeHandle::start(core);
        Ok(Server {
            listener,
            metrics_listener,
            handle,
            shutdown: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// The metrics endpoint's bound address, when enabled.
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics_listener.as_ref().and_then(|l| l.local_addr().ok())
    }

    /// Accept-and-serve until a shutdown request arrives, then drain
    /// and return.  Prints `metrics on <addr>` (when enabled) and
    /// `serving on <addr>` to stdout first (the CI smoke driver parses
    /// both to find the ephemeral ports).
    pub fn run(self) -> Result<()> {
        let Server { listener, metrics_listener, handle, shutdown } = self;
        let addr = listener.local_addr()?;
        let metrics_join = match metrics_listener {
            Some(ml) => {
                let maddr = ml.local_addr()?;
                println!("metrics on {maddr}");
                Some(spawn_metrics(Arc::clone(&handle.core), ml, Arc::clone(&shutdown)))
            }
            None => None,
        };
        println!("serving on {addr}");
        std::io::stdout().flush().ok();
        listener.set_nonblocking(true).context("nonblocking accept loop")?;
        while !shutdown.load(Ordering::Acquire) {
            match listener.accept() {
                Ok((stream, peer)) => {
                    stream.set_nonblocking(false).ok();
                    stream.set_nodelay(true).ok();
                    let reader = match stream.try_clone() {
                        Ok(r) => r,
                        Err(e) => {
                            eprintln!("[serve] dropping {peer}: {e}");
                            continue;
                        }
                    };
                    let core = Arc::clone(&handle.core);
                    let writer = Arc::new(Mutex::new(stream));
                    let flag = Arc::clone(&shutdown);
                    std::thread::spawn(move || {
                        if let Err(e) = handle_session(&core, reader, &writer, &flag) {
                            eprintln!("[serve] session {peer}: {e:#}");
                        }
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    eprintln!("[serve] accept error: {e}");
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
        }
        let core = Arc::clone(&handle.core);
        handle.shutdown(); // drain: every admitted request is answered
        if let Some(j) = metrics_join {
            let _ = j.join(); // exits on the same shutdown flag
        }
        eprintln!("[serve] drained; final stats: {}", core.stats_json());
        Ok(())
    }
}

/// Single-session mode over stdin/stdout (`ebs serve --stdin`): same
/// frames, no sockets.  EOF or a shutdown request drains and returns.
pub fn run_stdio(core: Arc<ServeCore>) -> Result<()> {
    let handle = ServeHandle::start(Arc::clone(&core));
    let shutdown = Arc::new(AtomicBool::new(false));
    let writer = Arc::new(Mutex::new(std::io::stdout()));
    let result = handle_session(&core, std::io::stdin().lock(), &writer, &shutdown);
    handle.shutdown();
    writer.lock().unwrap().flush().ok();
    eprintln!("[serve] drained; final stats: {}", core.stats_json());
    result
}

/// Decode-dispatch loop for one connection.  Returns on clean EOF, a
/// transport error, or a shutdown request (after acking + flipping
/// `shutdown`).  Protocol-level failures never die silently: the
/// client is sent an error frame carrying the cause first.
pub fn handle_session<R: Read, W: Write + Send + 'static>(
    core: &Arc<ServeCore>,
    mut reader: R,
    writer: &Arc<Mutex<W>>,
    shutdown: &AtomicBool,
) -> Result<()> {
    loop {
        let payload = match protocol::read_frame(&mut reader) {
            Ok(Some(p)) => p,
            Ok(None) => return Ok(()), // client hung up between frames
            Err(e) => {
                // Report the typed cause, then close: after a torn or
                // wrong-version frame the stream offset is garbage, so
                // resynchronizing is impossible — but the client gets
                // told exactly why (id 0: no frame to attribute it to).
                let resp =
                    Response::Error { id: 0, code: e.error_code(), msg: e.to_string() };
                let _ = send(writer, &resp);
                return if matches!(e, FrameError::Io(_)) { Err(e.into()) } else { Ok(()) };
            }
        };
        let req = match protocol::decode_request(&payload) {
            Ok(r) => r,
            Err(e) => {
                // Payload-level garbage: the frame boundary is intact,
                // so the session survives — report and keep reading.
                let resp =
                    Response::Error { id: 0, code: ERR_BAD_REQUEST, msg: format!("{e:#}") };
                send(writer, &resp)?;
                continue;
            }
        };
        match req {
            Request::Classify { id, model, count, images } => {
                let resident = match core.registry.resolve(&model) {
                    Ok(r) => r,
                    Err(e) => {
                        let resp = Response::Error {
                            id,
                            code: ERR_UNKNOWN_MODEL,
                            msg: e.to_string(),
                        };
                        send(writer, &resp)?;
                        continue;
                    }
                };
                let count = count as usize;
                let img_sz = resident.image_size();
                if count == 0 || images.len() != count * img_sz {
                    let msg = format!(
                        "classify request {id}: {} floats for count {count} \
                         (model '{}' image size {img_sz})",
                        images.len(),
                        resident.name,
                    );
                    send(writer, &Response::Error { id, code: ERR_BAD_REQUEST, msg })?;
                    continue;
                }
                let w = Arc::clone(writer);
                let submitted = core.submit_to(
                    &resident,
                    images,
                    count,
                    Box::new(move |preds| {
                        let labels = preds.iter().map(|&p| p as u32).collect();
                        let _ = send(&w, &Response::Classify { id, labels });
                    }),
                );
                if let Err(e) = submitted {
                    let code = match e {
                        SubmitError::Overloaded => ERR_OVERLOADED,
                        SubmitError::ShuttingDown => ERR_SHUTTING_DOWN,
                        SubmitError::UnknownModel => ERR_UNKNOWN_MODEL,
                    };
                    send(writer, &Response::Error { id, code, msg: e.to_string() })?;
                }
            }
            Request::Stats { id, model } => {
                let json = if model.is_empty() {
                    core.stats_json().to_string()
                } else {
                    match core.model_stats_json(&model) {
                        Ok(j) => j.to_string(),
                        Err(e) => {
                            let resp = Response::Error {
                                id,
                                code: ERR_UNKNOWN_MODEL,
                                msg: e.to_string(),
                            };
                            send(writer, &resp)?;
                            continue;
                        }
                    }
                };
                send(writer, &Response::Stats { id, json })?;
            }
            Request::Metrics { id } => {
                send(writer, &Response::Metrics { id, text: core.metrics_text() })?;
            }
            Request::Load { id, model, source } => match core.load_model(&model, &source) {
                Ok(resident) => {
                    let resp = Response::LoadAck {
                        id,
                        generation: resident.generation,
                        version: resident.version.clone(),
                    };
                    send(writer, &resp)?;
                }
                Err(e) => {
                    let resp =
                        Response::Error { id, code: ERR_LOAD_FAILED, msg: format!("{e:#}") };
                    send(writer, &resp)?;
                }
            },
            Request::Shutdown { id } => {
                send(writer, &Response::ShutdownAck { id })?;
                core.queue.close();
                shutdown.store(true, Ordering::Release);
                return Ok(());
            }
        }
    }
}

fn send<W: Write>(writer: &Arc<Mutex<W>>, resp: &Response) -> std::io::Result<()> {
    let frame = protocol::encode_response(resp);
    let mut g = writer.lock().unwrap();
    g.write_all(&frame)?;
    g.flush()
}

/// The HTTP metrics listener: minimal HTTP/1.1, one scrape per
/// connection, exits on the shared shutdown flag.
fn spawn_metrics(
    core: Arc<ServeCore>,
    listener: TcpListener,
    shutdown: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("ebs-metrics".into())
        .spawn(move || {
            if listener.set_nonblocking(true).is_err() {
                return;
            }
            while !shutdown.load(Ordering::Acquire) {
                match listener.accept() {
                    Ok((mut stream, _)) => {
                        stream.set_nonblocking(false).ok();
                        if let Err(e) = serve_scrape(&core, &mut stream) {
                            eprintln!("[serve] metrics scrape: {e}");
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(_) => std::thread::sleep(Duration::from_millis(10)),
                }
            }
        })
        .expect("spawning metrics listener")
}

/// Answer one Prometheus scrape: drain the request head, write the
/// text exposition body.  Any path serves the same body (the endpoint
/// has exactly one document).
fn serve_scrape(core: &ServeCore, stream: &mut TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500))).ok();
    let mut head = Vec::new();
    let mut buf = [0u8; 1024];
    loop {
        let n = stream.read(&mut buf)?;
        if n == 0 {
            break;
        }
        head.extend_from_slice(&buf[..n]);
        if head.windows(4).any(|w| w == b"\r\n\r\n") || head.len() > 8192 {
            break;
        }
    }
    let body = core.metrics_text();
    let response = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len(),
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()
}
