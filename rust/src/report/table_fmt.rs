//! Table assembly: collect rows, emit aligned Markdown + CSV.
//!
//! Every report generator funnels through this so EXPERIMENTS.md can
//! embed regenerated tables verbatim.

use std::path::Path;

use anyhow::Result;

/// A simple string table with pre-formatted cells.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Aligned GitHub-flavoured Markdown.
    pub fn to_markdown(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                let pad = widths[i] - cells[i].chars().count();
                line.push(' ');
                line.push_str(&cells[i]);
                line.push_str(&" ".repeat(pad + 1));
                line.push('|');
            }
            line
        };
        let mut out = format!("### {}\n\n", self.title);
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&"-".repeat(w + 2));
            out.push('|');
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Write both `<stem>.md` and `<stem>.csv` and echo the Markdown.
    pub fn write(&self, dir: &Path, stem: &str) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{stem}.md")), self.to_markdown())?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())?;
        println!("{}", self.to_markdown());
        println!("[report] wrote {}/{{{stem}.md,{stem}.csv}}", dir.display());
        Ok(())
    }
}

/// Format helpers used across reports.
pub fn pct(x: f64) -> String {
    format!("{:.2}", 100.0 * x)
}

pub fn mflops(x: f64) -> String {
    format!("{x:.2} M")
}

pub fn saving(x: f64) -> String {
    format!("{x:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_is_aligned_and_csv_parses() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["100".into(), "x".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a   | bb |"));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
    }
}
