#!/usr/bin/env python3
"""Smoke-test the `ebs serve` binary end to end (gateway tier).

Starts the release binary on ephemeral ports with TWO deterministic
synthetic models resident, discovers the input geometry via per-model
`stats` requests, fires a small concurrent load against both models,
performs one hot swap under that load (asserting the generation
advances and nothing is dropped), scrapes the Prometheus endpoint over
HTTP, asserts a v1 frame is refused with the versioned error, then
requests graceful shutdown and requires the process to drain and
exit 0.

Usage: serve_smoke.py <path-to-ebs-binary>

Wire format (DESIGN.md §15, protocol v2): every frame is
[0xEB][0x02][u32 LE len][payload]; payloads are
[u8 opcode][u32 LE request id][...]; strings are [u16 LE len][UTF-8].
"""

import json
import struct
import subprocess
import sys
import threading

MAGIC, VERSION = 0xEB, 0x02
OP_CLASSIFY, OP_STATS, OP_SHUTDOWN, OP_METRICS, OP_LOAD, OP_ERROR = 1, 2, 3, 4, 5, 0xFF
ERR_UNSUPPORTED_VERSION = 4

CLIENTS = 4
REQS_PER_CLIENT = 8
MODELS = ["a", "b"]


def frame(payload):
    return struct.pack("<BBI", MAGIC, VERSION, len(payload)) + payload


def wire_str(s):
    b = s.encode()
    return struct.pack("<H", len(b)) + b


def classify_req(rid, model, count, floats):
    body = struct.pack("<BI", OP_CLASSIFY, rid) + wire_str(model)
    body += struct.pack("<I", count)
    body += struct.pack(f"<{len(floats)}f", *floats)
    return frame(body)


def stats_req(rid, model):
    return frame(struct.pack("<BI", OP_STATS, rid) + wire_str(model))


def load_req(rid, model, source):
    return frame(struct.pack("<BI", OP_LOAD, rid) + wire_str(model) + wire_str(source))


def simple_req(op, rid):
    return frame(struct.pack("<BI", op, rid))


def recv_exact(sock, n):
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise EOFError("server hung up mid-frame")
        buf += chunk
    return buf


def read_frame(sock):
    magic, version, ln = struct.unpack("<BBI", recv_exact(sock, 6))
    assert (magic, version) == (MAGIC, VERSION), f"bad response header {magic:#x}/{version:#x}"
    return recv_exact(sock, ln)


def fetch_stats(sock, rid, model):
    sock.sendall(stats_req(rid, model))
    payload = read_frame(sock)
    op, got = struct.unpack("<BI", payload[:5])
    assert op == OP_STATS and got == rid, (op, got)
    return json.loads(payload[5:].decode())


def client_load(host, port, t, img_sz, classes, errors):
    import socket

    try:
        with socket.create_connection((host, port), timeout=30) as c:
            c.settimeout(30)
            for i in range(REQS_PER_CLIENT):
                rid = t * 1000 + i
                model = MODELS[(t + i) % len(MODELS)]
                # deterministic pseudo-image; values in [0, 1)
                floats = [((t * 31 + i * 7 + j) % 97) / 97.0 for j in range(img_sz)]
                c.sendall(classify_req(rid, model, 1, floats))
                payload = read_frame(c)
                op, got, count = struct.unpack("<BII", payload[:9])
                assert op == OP_CLASSIFY, f"opcode {op:#x} for request {rid}"
                assert got == rid and count == 1, (got, count)
                (label,) = struct.unpack("<I", payload[9:13])
                assert 0 <= label < classes, f"label {label} out of range"
    except Exception as e:  # noqa: BLE001 — collected and reported below
        errors.append((t, repr(e)))


def check_v1_rejection(host, port):
    """A bare length-prefixed (v1) frame must earn a versioned error."""
    import socket

    with socket.create_connection((host, port), timeout=30) as c:
        c.settimeout(30)
        c.sendall(struct.pack("<I", 5) + struct.pack("<BI", OP_STATS, 1))
        payload = read_frame(c)
        op, rid = struct.unpack("<BI", payload[:5])
        code = payload[5]
        assert (op, rid, code) == (OP_ERROR, 0, ERR_UNSUPPORTED_VERSION), (op, rid, code)
        msg = payload[6:].decode()
        assert "magic" in msg, f"error must carry the cause: {msg!r}"


def scrape_metrics(host, port):
    import socket

    with socket.create_connection((host, port), timeout=30) as c:
        c.settimeout(30)
        c.sendall(b"GET /metrics HTTP/1.1\r\nHost: smoke\r\n\r\n")
        buf = b""
        while True:
            chunk = c.recv(4096)
            if not chunk:
                break
            buf += chunk
    text = buf.decode()
    assert text.startswith("HTTP/1.1 200 OK"), text[:100]
    return text.split("\r\n\r\n", 1)[1]


def main():
    import socket

    if len(sys.argv) != 2:
        print(__doc__)
        return 2
    proc = subprocess.Popen(
        [
            sys.argv[1], "serve",
            "--model", "a=synthetic:11,b=synthetic:22",
            "--addr", "127.0.0.1:0", "--metrics-addr", "127.0.0.1:0",
            "--workers", "2", "--max-batch", "8",
        ],
        stdout=subprocess.PIPE,
    )
    try:
        # Banner order: "metrics on H:P" (when enabled), "serving on H:P".
        metrics_hp = None
        while True:
            line = proc.stdout.readline().decode()
            assert line, "server exited before printing its banner"
            if line.startswith("metrics on "):
                mh, mp = line.strip().rsplit(" ", 1)[-1].rsplit(":", 1)
                metrics_hp = (mh, int(mp))
            elif line.startswith("serving on "):
                host, port = line.strip().rsplit(" ", 1)[-1].rsplit(":", 1)
                port = int(port)
                break
        assert metrics_hp, "metrics banner must precede the serving banner"

        with socket.create_connection((host, port), timeout=30) as ctl:
            ctl.settimeout(30)
            stats_a = fetch_stats(ctl, 1, "a")
            img_sz = int(stats_a["input_hw"]) ** 2 * int(stats_a["input_ch"])
            classes = int(stats_a["classes"])
            assert int(stats_a["generation"]) >= 1, stats_a

            errors = []
            threads = [
                threading.Thread(target=client_load, args=(host, port, t, img_sz, classes, errors))
                for t in range(CLIENTS)
            ]
            for th in threads:
                th.start()
            # Hot swap model "a" while the clients are firing.
            ctl.sendall(load_req(2, "a", "synthetic:33"))
            payload = read_frame(ctl)
            op, rid = struct.unpack("<BI", payload[:5])
            assert (op, rid) == (OP_LOAD, 2), (op, rid)
            (generation,) = struct.unpack("<Q", payload[5:13])
            assert generation >= 3, f"swap generation {generation} must exceed both publishes"
            for th in threads:
                th.join()
            assert not errors, f"client failures: {errors}"

            # Global stats: both models answered everything admitted.
            total = fetch_stats(ctl, 3, "")
            want = CLIENTS * REQS_PER_CLIENT
            assert int(total["completed"]) >= want, total
            assert int(total["admitted"]) == int(total["completed"]), total
            assert int(total["batch_images_max"]) <= 8, total
            assert set(MODELS) <= set(total["models"]), total["models"].keys()
            swapped = fetch_stats(ctl, 4, "a")
            assert int(swapped["swaps"]) == 1, swapped
            assert int(swapped["generation"]) == generation, swapped

            # Prometheus scrape over HTTP.
            body = scrape_metrics(*metrics_hp)
            assert 'ebs_serve_swaps_total{model="a"} 1' in body, body
            assert 'ebs_serve_requests_total{model="b",outcome="completed"}' in body, body

            check_v1_rejection(host, port)

            ctl.sendall(simple_req(OP_SHUTDOWN, 5))
            payload = read_frame(ctl)
            op, rid = struct.unpack("<BI", payload[:5])
            assert (op, rid) == (OP_SHUTDOWN, 5), (op, rid)

        rc = proc.wait(timeout=60)
        assert rc == 0, f"server exited {rc} after graceful shutdown"
        print(
            f"[serve-smoke] OK: {want} requests over {len(MODELS)} models, "
            f"1 hot swap (gen {generation}), metrics scraped, v1 frame refused, "
            f"clean drain + exit 0"
        )
        return 0
    except BaseException:
        proc.kill()
        raise
    finally:
        if proc.poll() is None:
            proc.kill()
        proc.wait(timeout=10)


if __name__ == "__main__":
    sys.exit(main())
