//! Protocol v2 frame + payload decode: arbitrary bytes must yield a
//! typed `FrameError`/decode error, never a panic or unbounded
//! allocation.  Body shared with tier-1 via `ebs::fuzzing`.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    ebs::fuzzing::fuzz_protocol_decode(data);
});
