//! Cross-replica moment rendezvous for sync-BN (DESIGN.md §14).
//!
//! Every replica reaches each BN reduction point in the same order (the
//! network topology is fixed), so sync points need no tags: a call to
//! [`MomentHub::reduce`] is matched with the same call on every other
//! replica purely by sequence.  Each replica submits per-chunk f64
//! partial vectors for the chunks it owns; the *last* arriver combines
//! all chunk slots left-to-right in canonical chunk order — the fixed
//! association the shard-invariance rule requires — and every replica
//! leaves with a copy of the combined vector.
//!
//! Error discipline: a replica that fails mid-step calls
//! [`MomentHub::poison`] (the pool wrapper does this), which wakes every
//! waiter with an error instead of leaving them blocked at the barrier.

use std::sync::{Condvar, Mutex};

use anyhow::{ensure, Result};

/// A cross-replica moment reduction point, abstracted over transport
/// (DESIGN.md §18).  The in-process implementation is [`MomentHub`];
/// the cluster worker's implementation ships the partials to the
/// coordinator over the exec wire protocol and blocks for the combined
/// vector.  The numerics contract is shared: whoever combines does so
/// left-to-right in **global chunk order** on one thread, so every
/// implementation yields bit-identical results for the same partials.
pub trait MomentExchange {
    /// Submit per-chunk partials (`parts[i·m..(i+1)·m]` is global chunk
    /// `chunk0 + i`) and receive the canonical combined vector in
    /// `out`.  Blocks until every participant has submitted.
    fn reduce(&self, chunk0: usize, m: usize, parts: &[f64], out: &mut Vec<f64>) -> Result<()>;
}

impl MomentExchange for MomentHub {
    fn reduce(&self, chunk0: usize, m: usize, parts: &[f64], out: &mut Vec<f64>) -> Result<()> {
        MomentHub::reduce(self, chunk0, m, parts, out)
    }
}

/// Rendezvous + canonical combine for per-chunk f64 partials.
pub struct MomentHub {
    shards: usize,
    chunks: usize,
    state: Mutex<HubState>,
    cv: Condvar,
}

struct HubState {
    /// Completed rendezvous count (generation counter for the wait).
    round: u64,
    /// Replicas that have submitted in the current round.
    arrived: usize,
    /// Per-chunk partial vectors, indexed by global chunk id.
    slots: Vec<Vec<f64>>,
    /// Chunk-ordered sum of all slots (valid for the previous round).
    combined: Vec<f64>,
    poisoned: bool,
}

impl MomentHub {
    pub fn new(shards: usize, chunks: usize) -> MomentHub {
        assert!(shards >= 1 && chunks >= shards);
        MomentHub {
            shards,
            chunks,
            state: Mutex::new(HubState {
                round: 0,
                arrived: 0,
                slots: vec![Vec::new(); chunks],
                combined: Vec::new(),
                poisoned: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Submit this replica's per-chunk partials and block until every
    /// replica has done the same.  `parts` holds `k` chunk vectors of
    /// `m` elements each, chunk-major (`parts[i·m..(i+1)·m]` is global
    /// chunk `chunk0 + i`); `out` receives the combined vector.
    pub fn reduce(&self, chunk0: usize, m: usize, parts: &[f64], out: &mut Vec<f64>) -> Result<()> {
        ensure!(m > 0 && parts.len() % m == 0, "malformed moment submission");
        let k = parts.len() / m;
        ensure!(chunk0 + k <= self.chunks, "chunk submission out of range");
        let mut st = self.state.lock().unwrap();
        ensure!(!st.poisoned, "sharded step aborted by a failed replica");
        let round = st.round;
        for (i, part) in parts.chunks_exact(m).enumerate() {
            let slot = &mut st.slots[chunk0 + i];
            slot.clear();
            slot.extend_from_slice(part);
        }
        st.arrived += 1;
        if st.arrived == self.shards {
            let HubState { slots, combined, .. } = &mut *st;
            combined.clear();
            combined.resize(m, 0.0);
            for slot in slots.iter() {
                debug_assert_eq!(slot.len(), m, "sync point disagreement across replicas");
                for (o, &v) in combined.iter_mut().zip(slot) {
                    *o += v;
                }
            }
            st.arrived = 0;
            st.round += 1;
            self.cv.notify_all();
        } else {
            while st.round == round && !st.poisoned {
                st = self.cv.wait(st).unwrap();
            }
            ensure!(!st.poisoned, "sharded step aborted by a failed replica");
        }
        out.clear();
        out.extend_from_slice(&st.combined);
        Ok(())
    }

    /// Wake every waiter with an error; further `reduce` calls fail
    /// fast.  Idempotent.
    pub fn poison(&self) {
        let mut st = self.state.lock().unwrap();
        st.poisoned = true;
        self.cv.notify_all();
    }
}

/// The no-hub (single-replica) combine: the caller owns every chunk, so
/// the canonical chunk-ordered sum runs locally.  Kept next to the hub
/// so both paths share one definition of the combine order.
pub fn combine_local(m: usize, parts: &[f64], out: &mut Vec<f64>) {
    debug_assert!(m > 0 && parts.len() % m == 0);
    out.clear();
    out.resize(m, 0.0);
    for part in parts.chunks_exact(m) {
        for (o, &v) in out.iter_mut().zip(part) {
            *o += v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hub_combines_in_chunk_order_regardless_of_arrival() {
        // 2 shards × 2 chunks each; combined must equal the local
        // 4-chunk combine no matter which replica arrives last.
        let parts: Vec<Vec<f64>> = (0..4).map(|c| vec![c as f64 + 0.5, 10.0 * c as f64]).collect();
        let flat: Vec<f64> = parts.iter().flatten().copied().collect();
        let mut want = Vec::new();
        combine_local(2, &flat, &mut want);

        let hub = MomentHub::new(2, 4);
        let mut got = [Vec::new(), Vec::new()];
        std::thread::scope(|s| {
            let hub = &hub;
            let (g0, g1) = got.split_at_mut(1);
            let p01: Vec<f64> = parts[0].iter().chain(&parts[1]).copied().collect();
            let p23: Vec<f64> = parts[2].iter().chain(&parts[3]).copied().collect();
            s.spawn(move || hub.reduce(0, 2, &p01, &mut g0[0]).unwrap());
            s.spawn(move || hub.reduce(2, 2, &p23, &mut g1[0]).unwrap());
        });
        assert_eq!(got[0], want);
        assert_eq!(got[1], want);
    }

    #[test]
    fn hub_handles_sequential_rounds_and_poison() {
        // Two replicas, each running several back-to-back sync points:
        // round r's combine must never be clobbered before every
        // replica has read it.
        let hub = MomentHub::new(2, 2);
        std::thread::scope(|s| {
            let hub = &hub;
            for rep in 0..2usize {
                s.spawn(move || {
                    let mut out = Vec::new();
                    for round in 0..50u32 {
                        let mine = (rep as f64 + 1.0) * (round as f64 + 1.0);
                        hub.reduce(rep, 1, &[mine], &mut out).unwrap();
                        assert_eq!(out, vec![3.0 * (round as f64 + 1.0)], "round {round}");
                    }
                });
            }
        });
        hub.poison();
        let mut out = Vec::new();
        assert!(hub.reduce(0, 1, &[1.0], &mut out).is_err());
    }
}
