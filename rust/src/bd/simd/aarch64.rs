//! aarch64 NEON popcount kernel (DESIGN.md §17) — the paper's own
//! deployment ISA (§4.3 measures BD conv with NEON bit ops on ARM).
//!
//! `vcnt` counts bits per byte; three widening pairwise adds
//! (`vpaddl` u8→u16→u32→u64) fold the 16 byte counts into two u64 lane
//! sums that accumulate across the row.  Two words (one 128-bit
//! vector) per iteration, scalar tail for odd word counts.
//!
//! NEON is a baseline feature of every aarch64 target Rust's std
//! supports, so no runtime probe is needed and the intrinsics are safe
//! to reach whenever this module compiles at all.  Never compiled on
//! x86-64 — CI covers it only via review and the shared tier tests on
//! ARM hosts.

#![allow(unsafe_code)]

use core::arch::aarch64::{
    vaddq_u64, vandq_u64, vcntq_u8, vdupq_n_u64, vgetq_lane_u64, vld1q_u64, vpaddlq_u16,
    vpaddlq_u32, vpaddlq_u8, vreinterpretq_u8_u64,
};

/// Safe entry: NEON kernel (always available on aarch64).
pub fn neon(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len(), "bit rows must share a word width");
    let words = a.len().min(b.len());
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let mut i = 0usize;
    let mut total: u64 = 0;
    // SAFETY: loads stay within `words` (guarded by the loop bounds);
    // NEON is unconditionally present on aarch64.
    unsafe {
        let mut vacc = vdupq_n_u64(0);
        while i + 2 <= words {
            let and = vandq_u64(vld1q_u64(ap.add(i)), vld1q_u64(bp.add(i)));
            let bytes = vcntq_u8(vreinterpretq_u8_u64(and));
            vacc = vaddq_u64(vacc, vpaddlq_u32(vpaddlq_u16(vpaddlq_u8(bytes))));
            i += 2;
        }
        total += vgetq_lane_u64::<0>(vacc) + vgetq_lane_u64::<1>(vacc);
        while i < words {
            total += (*ap.add(i) & *bp.add(i)).count_ones() as u64;
            i += 1;
        }
    }
    total as u32
}
