//! DNAS supernet efficiency harness (Table 3).
//!
//! Runs N iterations of the `dnas_search` graph (N weight copies, N²
//! convolutions per layer — Fig. 2a) and of the EBS `search_det` graph
//! (one copy, one convolution — Fig. 2b) on identical data, recording
//! wall-clock and peak RSS.  The O(N)/O(N²) vs O(1)/O(1) gap is the
//! paper's Table 3 claim; see `report::table3` for the assembled table.

use std::time::Instant;

use anyhow::Result;

use crate::runtime::{Engine, StateVec, Tensor};
use crate::util::{mem, Rng};

/// Measured cost of running `iters` search iterations on one graph.
#[derive(Debug, Clone)]
pub struct StepCost {
    pub graph: String,
    pub iters: usize,
    pub total_seconds: f64,
    pub peak_rss_bytes: u64,
    pub state_bytes: usize,
}

/// Shared body of the search-step timing harness (Table 3 and the
/// shards sweep ride the same protocol): a seeded random-batch stream,
/// the fixed step-io literal, one untimed warmup step, then `iters`
/// timed steps through `step`.  One copy of the io keys and
/// hyperparameters, however the step is dispatched.
fn timed_search_steps(
    image: [usize; 3],
    batch: usize,
    classes: usize,
    iters: usize,
    seed: u64,
    step: &mut dyn FnMut(&[(String, Tensor)]) -> Result<()>,
) -> Result<f64> {
    let mut rng = Rng::new(seed);
    let [h, w, c] = image;
    let draw = move |rng: &mut Rng| -> (Tensor, Tensor) {
        (
            Tensor::from_f32(
                &[batch, h, w, c],
                (0..batch * h * w * c).map(|_| rng.normal()).collect(),
            ),
            Tensor::from_i32(&[batch], (0..batch).map(|_| rng.below(classes) as i32).collect()),
        )
    };
    let io = |xt: Tensor, yt: Tensor, xv: Tensor, yv: Tensor| {
        vec![
            ("xt".to_string(), xt),
            ("yt".to_string(), yt),
            ("xv".to_string(), xv),
            ("yv".to_string(), yv),
            ("lr_w".to_string(), Tensor::scalar_f32(0.01)),
            ("lr_arch".to_string(), Tensor::scalar_f32(0.02)),
            ("wd".to_string(), Tensor::scalar_f32(5e-4)),
            ("lam".to_string(), Tensor::scalar_f32(0.5)),
            ("target".to_string(), Tensor::scalar_f32(1.0)),
        ]
    };
    // Warmup (compile on PJRT, arena/replica growth on native) outside
    // the timed region.
    let (xt, yt) = draw(&mut rng);
    let (xv, yv) = draw(&mut rng);
    step(&io(xt, yt, xv, yv))?;
    let t0 = Instant::now();
    for _ in 0..iters {
        let (xt, yt) = draw(&mut rng);
        let (xv, yv) = draw(&mut rng);
        step(&io(xt, yt, xv, yv))?;
    }
    Ok(t0.elapsed().as_secs_f64())
}

/// Execute `iters` steps of `graph` ("search_det" or "dnas_search") with
/// random batches; returns wall-clock + memory accounting.
pub fn run_dnas_steps(
    engine: &mut Engine,
    graph: &str,
    state: &mut StateVec,
    iters: usize,
    seed: u64,
) -> Result<StepCost> {
    engine.prepare(graph)?;
    let (image, b, classes) =
        (engine.manifest.image, engine.manifest.batch_size, engine.manifest.num_classes);
    let total_seconds = timed_search_steps(image, b, classes, iters, seed, &mut |io| {
        engine.run(graph, state, io)?;
        Ok(())
    })?;
    Ok(StepCost {
        graph: graph.to_string(),
        iters,
        total_seconds,
        peak_rss_bytes: mem::peak_rss_bytes(),
        state_bytes: state.size_bytes(),
    })
}

/// [`run_dnas_steps`] through the sharded step executor — the
/// shards-sweep half of the `search_step` bench (DESIGN.md §14): the
/// identical step protocol, each step dispatched via
/// [`crate::exec::StepExecutor::step`] so it fans out over the
/// configured replicas.
pub fn run_sharded_search_steps(
    exec: &mut crate::exec::StepExecutor,
    state: &mut StateVec,
    iters: usize,
    seed: u64,
) -> Result<StepCost> {
    let (image, b, classes) =
        (exec.manifest.image, exec.manifest.batch_size, exec.manifest.num_classes);
    let total_seconds = timed_search_steps(image, b, classes, iters, seed, &mut |io| {
        exec.step("search_det", state, io)?;
        Ok(())
    })?;
    Ok(StepCost {
        graph: "search_det".to_string(),
        iters,
        total_seconds,
        peak_rss_bytes: mem::peak_rss_bytes(),
        state_bytes: state.size_bytes(),
    })
}

/// Wall-clock + wire accounting for a dataset-driven sharded run
/// ([`run_dataset_search_steps`]).  Byte figures are `None` when the
/// executor has no wire (in-process transport).
#[derive(Debug, Clone, Copy)]
pub struct DataStepCost {
    pub total_seconds: f64,
    /// Phase-data path bytes per training epoch — PhaseStart +
    /// DatasetLoad frames sent during the timed window, scaled to one
    /// epoch of the train split.  This is the traffic the wire mode
    /// moves (O(batch·H·W·C) payload vs O(batch) indices); state sync
    /// is identical in both modes and reported separately.
    pub wire_bytes_per_epoch: Option<f64>,
    /// StateSync bytes per epoch over the same window (mode-invariant;
    /// logged for the coordinator-summary observability story).
    pub sync_bytes_per_epoch: Option<f64>,
}

/// Dataset-driven variant of [`run_sharded_search_steps`]: batches are
/// drawn from a real [`crate::data::Dataset`] pair through the driver's
/// own `EpochBatcher` protocol, with the `xt_src`/`xv_src` index
/// side-channels attached — so a cluster transport in index wire mode
/// resolves them from worker-resident copies (DESIGN.md §18).  Wire
/// deltas are measured across the timed window only (warmup and the
/// one-time dataset ship excluded), then scaled to bytes/epoch.
pub fn run_dataset_search_steps(
    exec: &mut crate::exec::StepExecutor,
    state: &mut StateVec,
    train: &crate::data::Dataset,
    valid: &crate::data::Dataset,
    iters: usize,
    seed: u64,
) -> Result<DataStepCost> {
    use crate::data::{source_io, EpochBatcher};
    let batch = exec.manifest.batch_size;
    exec.host_dataset(0, train)?;
    exec.host_dataset(1, valid)?;
    let mut tb = EpochBatcher::new(train, batch, seed ^ 0x7214);
    let mut vb = EpochBatcher::new(valid, batch, seed ^ 0x88AA);
    let steps_per_epoch = tb.batches_per_epoch().max(1);
    let step = |exec: &mut crate::exec::StepExecutor,
                tb: &mut EpochBatcher,
                vb: &mut EpochBatcher,
                state: &mut StateVec| {
        let ti = tb.next_indices();
        let vi = vb.next_indices();
        let (xt, yt) = train.gather(&ti);
        let (xv, yv) = valid.gather(&vi);
        let io = vec![
            ("xt".to_string(), xt),
            ("yt".to_string(), yt),
            ("xv".to_string(), xv),
            ("yv".to_string(), yv),
            ("xt_src".to_string(), source_io(0, &ti)),
            ("xv_src".to_string(), source_io(1, &vi)),
            ("lr_w".to_string(), Tensor::scalar_f32(0.01)),
            ("lr_arch".to_string(), Tensor::scalar_f32(0.02)),
            ("wd".to_string(), Tensor::scalar_f32(5e-4)),
            ("lam".to_string(), Tensor::scalar_f32(0.5)),
            ("target".to_string(), Tensor::scalar_f32(1.0)),
        ];
        exec.step("search_det", state, &io).map(|_| ())
    };
    step(exec, &mut tb, &mut vb, state)?; // warmup
    let before = exec.wire_stats();
    let t0 = Instant::now();
    for _ in 0..iters {
        step(exec, &mut tb, &mut vb, state)?;
    }
    let total_seconds = t0.elapsed().as_secs_f64();
    let per_epoch = |sent: fn(&crate::exec::wire::WireTotals) -> u64| -> Option<f64> {
        let (b, a) = (before.as_ref()?, exec.wire_stats()?);
        Some(sent(&a).saturating_sub(sent(b)) as f64 / iters.max(1) as f64 * steps_per_epoch as f64)
    };
    use crate::exec::wire::{OP_DATASET_LOAD, OP_PHASE_START, OP_STATE_SYNC};
    Ok(DataStepCost {
        total_seconds,
        wire_bytes_per_epoch: per_epoch(|t| {
            t.per_op[OP_PHASE_START as usize].sent_bytes
                + t.per_op[OP_DATASET_LOAD as usize].sent_bytes
        }),
        sync_bytes_per_epoch: per_epoch(|t| t.per_op[OP_STATE_SYNC as usize].sent_bytes),
    })
}

/// Analytic memory model (the structural part of Table 3): bytes of
/// meta-weight copies held by each method for N candidate bitwidths.
pub fn weight_copy_bytes(engine: &Engine, n_candidates: usize) -> (usize, usize) {
    // EBS: one meta copy per quantized conv; DNAS: N copies (§4.1).
    let one: usize = engine
        .manifest
        .state_spec
        .iter()
        .filter(|l| {
            l.path.starts_with("state/params/")
                && l.path.ends_with("/w")
                && !l.path.contains("stem")
                && !l.path.contains("fc")
        })
        .map(|l| l.num_elements() * 4)
        .sum();
    (one, one * n_candidates)
}
